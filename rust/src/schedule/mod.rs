//! On-chip memory-access scheduling for parallel sparse kernels
//! (paper §5.3, Alg. 2, Figs. 4–6, 8–10).
//!
//! Problem: N' sparse kernels are processed in parallel; in each clock cycle
//! every active PE reads one input value from the (replicated) input-tile
//! BRAM. A tile has `r` replicas, so at most `r` *distinct* frequency
//! indices can be served per cycle, and each kernel contributes at most one
//! (value, index) per cycle. A schedule is a sequence of *sets*
//! `s_i = {(kernel, index), ...}` covering every non-zero of every kernel
//! exactly once; quality = few sets (cycles) ⇔ high PE utilization (Eq. 14).
//!
//! * [`exact_cover`] — the paper's greedy approximate exact-cover scheduler.
//! * [`baselines`] — *random* and *lowest-index-first* ([16]) comparators.
//! * [`tables`] — the INDEX/VALUE table encoding of Fig. 6 that the
//!   simulator's streaming controller consumes.

pub mod baselines;
pub mod exact_cover;
pub mod tables;

pub use baselines::{schedule_lowest_index, schedule_random};
pub use exact_cover::{exact_cover_work, schedule_exact_cover, schedule_exact_cover_budgeted};
pub use tables::{LayerSchedule, ScheduleStats, DEFAULT_WEIGHT_BANKS};

use crate::err;
use crate::sparse::SparseLayer;
use crate::util::error::Result;
use crate::util::rng::Pcg32;

/// One read cycle: the (kernel, index) pairs served together.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleSet {
    /// (kernel id within the group, flattened frequency index).
    pub reads: Vec<(u16, u16)>,
}

impl CycleSet {
    /// Distinct frequency indices this cycle (must be ≤ r).
    pub fn distinct_indices(&self) -> usize {
        let mut idx: Vec<u16> = self.reads.iter().map(|&(_, i)| i).collect();
        idx.sort_unstable();
        idx.dedup();
        idx.len()
    }

    /// Active PEs this cycle = kernels served.
    pub fn active_kernels(&self) -> usize {
        self.reads.len()
    }
}

/// A full schedule for one kernel group (the `S*` of Alg. 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub sets: Vec<CycleSet>,
    /// The replica bound r the schedule was built for.
    pub replicas: usize,
    /// Number of kernels in the group (PE_total per tile lane).
    pub num_kernels: usize,
}

impl Schedule {
    pub fn cycles(&self) -> usize {
        self.sets.len()
    }

    pub fn total_reads(&self) -> usize {
        self.sets.iter().map(|s| s.reads.len()).sum()
    }

    /// PE utilization (paper Eq. 14), for a single tile lane:
    /// `μ = Σ_t PE_on_t / (T · N')`. Broadcasting to P' tiles multiplies
    /// both numerator and denominator by P', leaving μ unchanged.
    pub fn pe_utilization(&self) -> f64 {
        if self.sets.is_empty() {
            return 1.0;
        }
        self.total_reads() as f64 / (self.cycles() * self.num_kernels) as f64
    }

    /// Information-theoretic lower bound on cycles for this workload:
    /// every kernel needs `nnz_k` cycles (one value per cycle), and at most
    /// `num_kernels` reads happen per cycle.
    pub fn lower_bound(kernels: &[Vec<u16>], _replicas: usize) -> usize {
        let max_nnz = kernels.iter().map(|k| k.len()).max().unwrap_or(0);
        let total: usize = kernels.iter().map(|k| k.len()).sum();
        let n = kernels.len().max(1);
        max_nnz.max(total.div_ceil(n))
    }

    /// Validate the exact-cover invariants against the source kernels:
    /// (C1) one read per kernel per cycle, (C2) ≤ r distinct indices per
    /// cycle, and every (kernel, index) edge covered exactly once.
    pub fn validate(&self, kernels: &[Vec<u16>]) -> Result<(), String> {
        use std::collections::HashSet;
        let mut covered: HashSet<(u16, u16)> = HashSet::new();
        for (c, set) in self.sets.iter().enumerate() {
            let mut seen_kernels = HashSet::new();
            for &(k, i) in &set.reads {
                if !seen_kernels.insert(k) {
                    return Err(format!("cycle {c}: kernel {k} read twice (C1)"));
                }
                if !covered.insert((k, i)) {
                    return Err(format!("cycle {c}: edge ({k},{i}) covered twice"));
                }
                let kk = kernels
                    .get(k as usize)
                    .ok_or_else(|| format!("cycle {c}: kernel {k} out of range"))?;
                if !kk.contains(&i) {
                    return Err(format!("cycle {c}: ({k},{i}) not a non-zero"));
                }
            }
            if set.distinct_indices() > self.replicas {
                return Err(format!(
                    "cycle {c}: {} distinct indices > r={} (C2)",
                    set.distinct_indices(),
                    self.replicas
                ));
            }
        }
        let total_edges: usize = kernels.iter().map(|k| k.len()).sum();
        if covered.len() != total_edges {
            return Err(format!(
                "covered {} of {} edges",
                covered.len(),
                total_edges
            ));
        }
        Ok(())
    }
}

/// Scheduling strategy selector (benches sweep all three).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheduler {
    ExactCover,
    LowestIndexFirst,
    Random,
}

impl Scheduler {
    pub const ALL: [Scheduler; 3] =
        [Scheduler::ExactCover, Scheduler::LowestIndexFirst, Scheduler::Random];

    pub fn label(&self) -> &'static str {
        match self {
            Scheduler::ExactCover => "exact-cover (this work)",
            Scheduler::LowestIndexFirst => "lowest-index-first [16]",
            Scheduler::Random => "random",
        }
    }

    /// Schedule one kernel group. `seed` only affects [`Scheduler::Random`].
    pub fn run(&self, kernels: &[Vec<u16>], replicas: usize, seed: u64) -> Schedule {
        match self {
            Scheduler::ExactCover => schedule_exact_cover(kernels, replicas),
            Scheduler::LowestIndexFirst => baselines::schedule_lowest_index(kernels, replicas),
            Scheduler::Random => baselines::schedule_random(kernels, replicas, seed),
        }
    }
}

/// Sampled MAC-weighted PE utilization of one scheduler over a pruned
/// layer's kernel groups (the Fig. 8/9/10 measurement). Samples
/// `samples.min(total)` of the layer's `num_groups × cin` scheduling
/// instances with a `seed`-derived pick set; per-instance scheduler seed is
/// the instance id, so runs are reproducible across callers.
///
/// This is the one shared implementation behind the `schedule` CLI
/// subcommand, `bench_scheduling`, and `scheduler_demo` — they used to carry
/// three copies of this loop. One deliberate behavior change rode along:
/// the slot denominator is `cycles · min(n_par, group kernels)` (the bench
/// copies' form), not the CLI copy's old `cycles · n_par` — lanes that
/// don't exist in a ragged last group no longer count as idle, so the CLI
/// now reports slightly *higher* utilization for layers whose cout is not
/// a multiple of N'.
pub fn sampled_layer_utilization(
    layer: &SparseLayer,
    sch: Scheduler,
    n_par: usize,
    replicas: usize,
    samples: usize,
    seed: u64,
) -> f64 {
    let total = layer.num_groups(n_par) * layer.cin;
    let picks = Pcg32::new(seed).sample_indices(total, samples.min(total));
    let (mut reads, mut slots) = (0u64, 0u64);
    for p in picks {
        let (g, m) = (p / layer.cin, p % layer.cin);
        let s = sch.run(&layer.group_indices(g, n_par, m), replicas, p as u64);
        reads += s.total_reads() as u64;
        slots += (s.cycles() * n_par.min(s.num_kernels)) as u64;
    }
    if slots == 0 {
        return 1.0;
    }
    reads as f64 / slots as f64
}

/// Work budget above which [`SchedulePolicy::ExactCover`] falls back to
/// lowest-index-first for a group (see [`exact_cover_work`]). Paper-scale
/// groups (64 kernels × 16 nnz ⇒ 64Ki work units) sit ~3 orders of
/// magnitude below this; the budget only trips on degenerate manifests.
pub const EXACT_COVER_WORK_BUDGET: u64 = 1 << 26;

/// Execution-facing scheduling policy — what the serving path runs, as
/// opposed to [`Scheduler`], which the figure benches sweep (it adds the
/// paper's `random` comparator, never wanted in serving). CLI surface:
/// `--scheduler {exact-cover,lowest-index,off}` on `infer`/`serve`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulePolicy {
    /// Alg. 2 exact cover, with lowest-index fallback on trivial or
    /// over-budget groups. The serving default.
    #[default]
    ExactCover,
    /// Lowest-index-first everywhere ([16]'s scheme).
    LowestIndex,
    /// No scheduling: the sparse MAC walks CSR rows in storage order
    /// (PR 3 behavior).
    Off,
}

impl SchedulePolicy {
    pub const ALL: [SchedulePolicy; 3] =
        [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex, SchedulePolicy::Off];

    /// Parse the CLI spelling. The single constructor every selection site
    /// (CLI flags, engine startup, benches) goes through.
    pub fn parse(name: &str) -> Result<SchedulePolicy> {
        match name {
            "exact-cover" | "ec" => Ok(SchedulePolicy::ExactCover),
            "lowest-index" | "li" => Ok(SchedulePolicy::LowestIndex),
            "off" | "none" => Ok(SchedulePolicy::Off),
            other => Err(err!(
                "unknown scheduler {other:?} (expected exact-cover|lowest-index|off)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedulePolicy::ExactCover => "exact-cover",
            SchedulePolicy::LowestIndex => "lowest-index",
            SchedulePolicy::Off => "off",
        }
    }

    /// Plan one kernel group under this policy. `None` means "execute
    /// unscheduled" ([`SchedulePolicy::Off`]). Exact cover degrades to
    /// lowest-index-first when the group is trivial (≤ 1 kernel — every
    /// schedule is optimal) or over [`EXACT_COVER_WORK_BUDGET`]; both
    /// fallbacks keep planning deterministic and cheap.
    pub fn plan_group(&self, kernels: &[Vec<u16>], replicas: usize) -> Option<Schedule> {
        match self {
            SchedulePolicy::Off => None,
            SchedulePolicy::LowestIndex => Some(schedule_lowest_index(kernels, replicas)),
            SchedulePolicy::ExactCover => {
                if kernels.len() <= 1 {
                    return Some(schedule_lowest_index(kernels, replicas));
                }
                schedule_exact_cover_budgeted(kernels, replicas, EXACT_COVER_WORK_BUDGET)
                    .or_else(|| Some(schedule_lowest_index(kernels, replicas)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_set_counts() {
        let s = CycleSet { reads: vec![(0, 5), (1, 5), (2, 9)] };
        assert_eq!(s.distinct_indices(), 2);
        assert_eq!(s.active_kernels(), 3);
    }

    #[test]
    fn utilization_bounds() {
        let sched = Schedule {
            sets: vec![
                CycleSet { reads: vec![(0, 1), (1, 1)] },
                CycleSet { reads: vec![(0, 2)] },
            ],
            replicas: 2,
            num_kernels: 2,
        };
        // 3 reads over 2 cycles * 2 PEs = 0.75
        assert!((sched.pe_utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lower_bound_cases() {
        // one kernel with 5 nnz dominates
        assert_eq!(Schedule::lower_bound(&[vec![0, 1, 2, 3, 4], vec![0]], 4), 5);
        // balanced: total/n
        assert_eq!(Schedule::lower_bound(&[vec![0, 1], vec![2, 3], vec![4, 5]], 1), 2);
        assert_eq!(Schedule::lower_bound(&[], 4), 0);
    }

    #[test]
    fn policy_parse_and_labels() {
        for p in SchedulePolicy::ALL {
            assert_eq!(SchedulePolicy::parse(p.label()).unwrap(), p);
        }
        assert_eq!(SchedulePolicy::parse("ec").unwrap(), SchedulePolicy::ExactCover);
        assert_eq!(SchedulePolicy::parse("none").unwrap(), SchedulePolicy::Off);
        assert!(SchedulePolicy::parse("random").is_err());
        assert_eq!(SchedulePolicy::default(), SchedulePolicy::ExactCover);
    }

    #[test]
    fn policy_plan_group_modes() {
        let kernels = vec![vec![0u16, 3], vec![1, 3], vec![0, 1]];
        assert!(SchedulePolicy::Off.plan_group(&kernels, 4).is_none());
        for p in [SchedulePolicy::ExactCover, SchedulePolicy::LowestIndex] {
            let s = p.plan_group(&kernels, 4).unwrap();
            s.validate(&kernels).unwrap();
        }
        // trivial group (1 kernel): exact cover falls back but still covers
        let one = vec![vec![2u16, 5, 9]];
        let s = SchedulePolicy::ExactCover.plan_group(&one, 1).unwrap();
        s.validate(&one).unwrap();
        assert_eq!(s.cycles(), 3);
    }

    #[test]
    fn sampled_utilization_in_unit_range() {
        use crate::sparse::prune_random;
        let mut rng = Pcg32::new(17);
        let layer = prune_random(32, 3, 8, 4, &mut rng);
        for sch in Scheduler::ALL {
            let u = sampled_layer_utilization(&layer, sch, 16, 8, 6, 7);
            assert!(u > 0.0 && u <= 1.0 + 1e-12, "{sch:?}: {u}");
        }
        // reproducible for a fixed seed
        let a = sampled_layer_utilization(&layer, Scheduler::ExactCover, 16, 8, 6, 7);
        let b = sampled_layer_utilization(&layer, Scheduler::ExactCover, 16, 8, 6, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn validate_catches_violations() {
        let kernels = vec![vec![1u16, 2], vec![1]];
        // duplicate kernel in one cycle
        let bad = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 1), (0, 2)] }],
            replicas: 8,
            num_kernels: 2,
        };
        assert!(bad.validate(&kernels).unwrap_err().contains("C1"));
        // replica violation
        let bad2 = Schedule {
            sets: vec![
                CycleSet { reads: vec![(0, 1), (1, 1)] },
                CycleSet { reads: vec![(0, 2)] },
            ],
            replicas: 0,
            num_kernels: 2,
        };
        assert!(bad2.validate(&kernels).unwrap_err().contains("C2"));
        // incomplete cover
        let bad3 = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 1)] }],
            replicas: 8,
            num_kernels: 2,
        };
        assert!(bad3.validate(&kernels).unwrap_err().contains("covered"));
    }
}
