//! On-chip memory-access scheduling for parallel sparse kernels
//! (paper §5.3, Alg. 2, Figs. 4–6, 8–10).
//!
//! Problem: N' sparse kernels are processed in parallel; in each clock cycle
//! every active PE reads one input value from the (replicated) input-tile
//! BRAM. A tile has `r` replicas, so at most `r` *distinct* frequency
//! indices can be served per cycle, and each kernel contributes at most one
//! (value, index) per cycle. A schedule is a sequence of *sets*
//! `s_i = {(kernel, index), ...}` covering every non-zero of every kernel
//! exactly once; quality = few sets (cycles) ⇔ high PE utilization (Eq. 14).
//!
//! * [`exact_cover`] — the paper's greedy approximate exact-cover scheduler.
//! * [`baselines`] — *random* and *lowest-index-first* ([16]) comparators.
//! * [`tables`] — the INDEX/VALUE table encoding of Fig. 6 that the
//!   simulator's streaming controller consumes.

pub mod baselines;
pub mod exact_cover;
pub mod tables;

pub use baselines::{schedule_lowest_index, schedule_random};
pub use exact_cover::schedule_exact_cover;

/// One read cycle: the (kernel, index) pairs served together.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleSet {
    /// (kernel id within the group, flattened frequency index).
    pub reads: Vec<(u16, u16)>,
}

impl CycleSet {
    /// Distinct frequency indices this cycle (must be ≤ r).
    pub fn distinct_indices(&self) -> usize {
        let mut idx: Vec<u16> = self.reads.iter().map(|&(_, i)| i).collect();
        idx.sort_unstable();
        idx.dedup();
        idx.len()
    }

    /// Active PEs this cycle = kernels served.
    pub fn active_kernels(&self) -> usize {
        self.reads.len()
    }
}

/// A full schedule for one kernel group (the `S*` of Alg. 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub sets: Vec<CycleSet>,
    /// The replica bound r the schedule was built for.
    pub replicas: usize,
    /// Number of kernels in the group (PE_total per tile lane).
    pub num_kernels: usize,
}

impl Schedule {
    pub fn cycles(&self) -> usize {
        self.sets.len()
    }

    pub fn total_reads(&self) -> usize {
        self.sets.iter().map(|s| s.reads.len()).sum()
    }

    /// PE utilization (paper Eq. 14), for a single tile lane:
    /// `μ = Σ_t PE_on_t / (T · N')`. Broadcasting to P' tiles multiplies
    /// both numerator and denominator by P', leaving μ unchanged.
    pub fn pe_utilization(&self) -> f64 {
        if self.sets.is_empty() {
            return 1.0;
        }
        self.total_reads() as f64 / (self.cycles() * self.num_kernels) as f64
    }

    /// Information-theoretic lower bound on cycles for this workload:
    /// every kernel needs `nnz_k` cycles (one value per cycle), and at most
    /// `num_kernels` reads happen per cycle.
    pub fn lower_bound(kernels: &[Vec<u16>], _replicas: usize) -> usize {
        let max_nnz = kernels.iter().map(|k| k.len()).max().unwrap_or(0);
        let total: usize = kernels.iter().map(|k| k.len()).sum();
        let n = kernels.len().max(1);
        max_nnz.max(total.div_ceil(n))
    }

    /// Validate the exact-cover invariants against the source kernels:
    /// (C1) one read per kernel per cycle, (C2) ≤ r distinct indices per
    /// cycle, and every (kernel, index) edge covered exactly once.
    pub fn validate(&self, kernels: &[Vec<u16>]) -> Result<(), String> {
        use std::collections::HashSet;
        let mut covered: HashSet<(u16, u16)> = HashSet::new();
        for (c, set) in self.sets.iter().enumerate() {
            let mut seen_kernels = HashSet::new();
            for &(k, i) in &set.reads {
                if !seen_kernels.insert(k) {
                    return Err(format!("cycle {c}: kernel {k} read twice (C1)"));
                }
                if !covered.insert((k, i)) {
                    return Err(format!("cycle {c}: edge ({k},{i}) covered twice"));
                }
                let kk = kernels
                    .get(k as usize)
                    .ok_or_else(|| format!("cycle {c}: kernel {k} out of range"))?;
                if !kk.contains(&i) {
                    return Err(format!("cycle {c}: ({k},{i}) not a non-zero"));
                }
            }
            if set.distinct_indices() > self.replicas {
                return Err(format!(
                    "cycle {c}: {} distinct indices > r={} (C2)",
                    set.distinct_indices(),
                    self.replicas
                ));
            }
        }
        let total_edges: usize = kernels.iter().map(|k| k.len()).sum();
        if covered.len() != total_edges {
            return Err(format!(
                "covered {} of {} edges",
                covered.len(),
                total_edges
            ));
        }
        Ok(())
    }
}

/// Scheduling strategy selector (benches sweep all three).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheduler {
    ExactCover,
    LowestIndexFirst,
    Random,
}

impl Scheduler {
    pub const ALL: [Scheduler; 3] =
        [Scheduler::ExactCover, Scheduler::LowestIndexFirst, Scheduler::Random];

    pub fn label(&self) -> &'static str {
        match self {
            Scheduler::ExactCover => "exact-cover (this work)",
            Scheduler::LowestIndexFirst => "lowest-index-first [16]",
            Scheduler::Random => "random",
        }
    }

    /// Schedule one kernel group. `seed` only affects [`Scheduler::Random`].
    pub fn run(&self, kernels: &[Vec<u16>], replicas: usize, seed: u64) -> Schedule {
        match self {
            Scheduler::ExactCover => schedule_exact_cover(kernels, replicas),
            Scheduler::LowestIndexFirst => baselines::schedule_lowest_index(kernels, replicas),
            Scheduler::Random => baselines::schedule_random(kernels, replicas, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_set_counts() {
        let s = CycleSet { reads: vec![(0, 5), (1, 5), (2, 9)] };
        assert_eq!(s.distinct_indices(), 2);
        assert_eq!(s.active_kernels(), 3);
    }

    #[test]
    fn utilization_bounds() {
        let sched = Schedule {
            sets: vec![
                CycleSet { reads: vec![(0, 1), (1, 1)] },
                CycleSet { reads: vec![(0, 2)] },
            ],
            replicas: 2,
            num_kernels: 2,
        };
        // 3 reads over 2 cycles * 2 PEs = 0.75
        assert!((sched.pe_utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lower_bound_cases() {
        // one kernel with 5 nnz dominates
        assert_eq!(Schedule::lower_bound(&[vec![0, 1, 2, 3, 4], vec![0]], 4), 5);
        // balanced: total/n
        assert_eq!(Schedule::lower_bound(&[vec![0, 1], vec![2, 3], vec![4, 5]], 1), 2);
        assert_eq!(Schedule::lower_bound(&[], 4), 0);
    }

    #[test]
    fn validate_catches_violations() {
        let kernels = vec![vec![1u16, 2], vec![1]];
        // duplicate kernel in one cycle
        let bad = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 1), (0, 2)] }],
            replicas: 8,
            num_kernels: 2,
        };
        assert!(bad.validate(&kernels).unwrap_err().contains("C1"));
        // replica violation
        let bad2 = Schedule {
            sets: vec![
                CycleSet { reads: vec![(0, 1), (1, 1)] },
                CycleSet { reads: vec![(0, 2)] },
            ],
            replicas: 0,
            num_kernels: 2,
        };
        assert!(bad2.validate(&kernels).unwrap_err().contains("C2"));
        // incomplete cover
        let bad3 = Schedule {
            sets: vec![CycleSet { reads: vec![(0, 1)] }],
            replicas: 8,
            num_kernels: 2,
        };
        assert!(bad3.validate(&kernels).unwrap_err().contains("covered"));
    }
}
