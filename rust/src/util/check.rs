//! Mini property-testing framework (offline substitute for `proptest`).
//!
//! Usage pattern throughout the test suite:
//!
//! ```
//! use spectral_flow::util::check::forall;
//! forall("sum is commutative", 200, |rng| {
//!     let a = rng.below(1000) as u64;
//!     let b = rng.below(1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a fresh [`Pcg32`] derived from a base seed and the case
//! index; on failure the panic message names the property and the exact
//! failing case seed so the case reproduces in isolation via
//! [`reproduce`]. `SF_CHECK_SEED` overrides the base seed, `SF_CHECK_CASES`
//! scales case counts (both read once per call).

use super::rng::Pcg32;

const DEFAULT_BASE_SEED: u64 = 0x5EC7_2A1F;

fn base_seed() -> u64 {
    std::env::var("SF_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_BASE_SEED)
}

fn scaled(cases: usize) -> usize {
    let scale: f64 = std::env::var("SF_CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    ((cases as f64 * scale) as usize).max(1)
}

/// Seed for case `i` of a property (public so failures can be replayed).
pub fn case_seed(base: u64, i: usize) -> u64 {
    base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run `prop` over `cases` random cases. Panics (with the case seed) on the
/// first failing case. The property signals failure by panicking.
pub fn forall<F: FnMut(&mut Pcg32)>(name: &str, cases: usize, mut prop: F) {
    let base = base_seed();
    for i in 0..scaled(cases) {
        let seed = case_seed(base, i);
        let mut rng = Pcg32::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}): {msg}\n\
                 reproduce with: spectral_flow::util::check::reproduce({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn reproduce<F: FnOnce(&mut Pcg32)>(seed: u64, prop: F) {
    let mut rng = Pcg32::new(seed);
    prop(&mut rng);
}

/// Assert two f32 slices match within tolerance, with a useful diff message.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch: {} vs {}", got.len(), want.len());
    let mut worst = (0usize, 0.0f32);
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let err = (g - w).abs();
        if err > tol && err > worst.1 {
            worst = (i, err);
        }
    }
    if worst.1 > 0.0 {
        let i = worst.0;
        panic!(
            "allclose failed: worst at [{i}]: got {} want {} (|err| {} > atol {} + rtol {} * |want|); \
             {} / {} elements out of tolerance",
            got[i], want[i], worst.1, atol, rtol,
            got.iter().zip(want).filter(|(g, w)| (*g - *w).abs() > atol + rtol * w.abs()).count(),
            got.len(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |_| count += 1);
        assert_eq!(count, scaled(50));
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always-fails", 5, |_| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("always-fails"));
        assert!(msg.contains("seed"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn case_seeds_are_deterministic_and_distinct() {
        let s: Vec<u64> = (0..10).map(|i| case_seed(1, i)).collect();
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert_eq!(s, (0..10).map(|i| case_seed(1, i)).collect::<Vec<_>>());
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-5);
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0, 2.0], &[1.5, 2.0], 1e-5, 1e-5);
        });
        assert!(r.is_err());
    }
}
