//! Tiny CLI flag parser (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown flags are collected so callers can reject or ignore them; `help()`
//! renders a usage block from the registered options.

use std::collections::BTreeMap;

/// Parsed arguments plus registered option metadata for help text.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    registered: Vec<(String, String, String)>, // (name, default, help)
    program: String,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the program name).
    pub fn from_env() -> Self {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_default();
        let mut a = Self::parse(it.collect());
        a.program = program;
        a
    }

    /// Parse from an explicit token list (used by tests).
    pub fn parse(tokens: Vec<String>) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    flags.insert(body.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(t.clone());
            }
            i += 1;
        }
        Args { flags, positional, registered: Vec::new(), program: String::new() }
    }

    /// Register an option (for help text) and fetch it with a default.
    pub fn opt(&mut self, name: &str, default: &str, help: &str) -> String {
        self.registered.push((name.to_string(), default.to_string(), help.to_string()));
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_usize(&mut self, name: &str, default: usize, help: &str) -> usize {
        self.opt(name, &default.to_string(), help)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn opt_f64(&mut self, name: &str, default: f64, help: &str) -> f64 {
        self.opt(name, &default.to_string(), help)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn opt_bool(&mut self, name: &str, help: &str) -> bool {
        self.registered.push((name.to_string(), "false".to_string(), help.to_string()));
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Render usage text from registered options.
    pub fn help(&self, about: &str) -> String {
        let mut out = format!("{about}\n\nOptions:\n");
        for (name, default, help) in &self.registered {
            out.push_str(&format!("  --{name:<18} {help} (default: {default})\n"));
        }
        out
    }

    /// If `--help` was passed, print usage and exit.
    pub fn maybe_help(&self, about: &str) {
        if self.has("help") {
            println!("{}", self.help(about));
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_key_value_styles() {
        // NOTE grammar: a bare `--flag` greedily binds the next non-flag
        // token as its value, so boolean flags go last or use `--flag=true`
        // (subcommands always come first in this CLI).
        let mut a = Args::parse(toks("run --n 64 --replicas=10 --verbose"));
        assert_eq!(a.opt_usize("n", 0, ""), 64);
        assert_eq!(a.opt_usize("replicas", 0, ""), 10);
        assert!(a.opt_bool("verbose", ""));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(vec![]);
        assert_eq!(a.opt("variant", "vgg16-224", ""), "vgg16-224");
        assert_eq!(a.opt_f64("alpha", 4.0, ""), 4.0);
        assert!(!a.opt_bool("quick", ""));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let mut a = Args::parse(toks("--quick"));
        assert!(a.opt_bool("quick", ""));
    }

    #[test]
    fn help_lists_registered() {
        let mut a = Args::parse(vec![]);
        a.opt("alpha", "4", "compression ratio");
        let h = a.help("demo");
        assert!(h.contains("--alpha"));
        assert!(h.contains("compression ratio"));
    }
}
