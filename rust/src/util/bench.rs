//! Micro-benchmark harness (offline substitute for `criterion`).
//!
//! All `cargo bench` targets in `rust/benches/` are `harness = false`
//! binaries built on this module. The methodology mirrors criterion's core:
//! warmup, then timed iterations, reporting median / p10 / p90 and
//! mean±stddev. Results are printed as aligned text and optionally appended
//! to a CSV so EXPERIMENTS.md can cite exact numbers.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<52} {:>12} (median {:>12}, p10 {:>12}, p90 {:>12}, n={})",
            self.name,
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.median_ns),
            Self::fmt_ns(self.p10_ns),
            Self::fmt_ns(self.p90_ns),
            self.iters,
        )
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{:.1},{:.1},{:.1},{:.1}",
            self.name, self.iters, self.mean_ns, self.stddev_ns,
            self.median_ns, self.p10_ns, self.p90_ns
        )
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 1000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI-ish runs (shorter budget).
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_iters: 3,
            max_iters: 200,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run `f` repeatedly, using its return value to defeat dead-code elim.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup: also estimates per-iteration cost.
        let wstart = Instant::now();
        let mut wit = 0usize;
        while wstart.elapsed() < self.warmup || wit == 0 {
            std::hint::black_box(f());
            wit += 1;
            if wit >= self.max_iters {
                break;
            }
        }
        let est = wstart.elapsed().as_secs_f64() / wit as f64;
        let target = (self.budget.as_secs_f64() / est.max(1e-9)) as usize;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record a fully pre-computed measurement (e.g. the load generator's
    /// latency percentiles, which are aggregated outside this harness).
    pub fn push(&mut self, m: Measurement) {
        println!("{}", m.report());
        self.results.push(m);
    }

    /// Record an externally measured value (e.g. a one-shot end-to-end run).
    pub fn record(&mut self, name: &str, elapsed: Duration, iters: usize) {
        let ns = elapsed.as_nanos() as f64 / iters.max(1) as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: ns,
            stddev_ns: 0.0,
            median_ns: ns,
            p10_ns: ns,
            p90_ns: ns,
        };
        println!("{}", m.report());
        self.results.push(m);
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Append all results to a CSV file (with header if new).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        ensure_parent_dir(path)?;
        let new = !std::path::Path::new(path).exists();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "name,iters,mean_ns,stddev_ns,median_ns,p10_ns,p90_ns")?;
        }
        for m in &self.results {
            writeln!(f, "{}", m.csv_row())?;
        }
        Ok(())
    }

    /// Overwrite `path` with all results as a JSON array — the
    /// machine-readable bench artifact CI uploads (`BENCH_*.json`).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        use std::io::Write;
        ensure_parent_dir(path)?;
        let arr = Json::Arr(self.results.iter().map(measurement_to_json).collect());
        let text = arr.to_string();
        let mut f = std::fs::File::create(path)?;
        f.write_all(text.as_bytes())?;
        writeln!(f)?;
        Ok(())
    }
}

fn measurement_to_json(m: &Measurement) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(m.name.clone()));
    o.insert("iters".to_string(), Json::Num(m.iters as f64));
    o.insert("mean_ns".to_string(), Json::Num(m.mean_ns));
    o.insert("stddev_ns".to_string(), Json::Num(m.stddev_ns));
    o.insert("median_ns".to_string(), Json::Num(m.median_ns));
    o.insert("p10_ns".to_string(), Json::Num(m.p10_ns));
    o.insert("p90_ns".to_string(), Json::Num(m.p90_ns));
    Json::Obj(o)
}

/// Write `results` as a **measured** baseline artifact (the wrapped
/// `{meta, results}` form with `provenance: "measured"`), which is what
/// arms the CI bench-regression gate. `spectral-flow bench-check
/// --update-baseline` calls this with a freshly generated artifact.
pub fn write_measured_baseline(
    path: &str,
    results: &[Measurement],
    note: &str,
) -> std::io::Result<()> {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::io::Write;
    ensure_parent_dir(path)?;
    let mut meta = BTreeMap::new();
    meta.insert("provenance".to_string(), Json::Str("measured".to_string()));
    meta.insert("note".to_string(), Json::Str(note.to_string()));
    let mut root = BTreeMap::new();
    root.insert("meta".to_string(), Json::Obj(meta));
    root.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(measurement_to_json).collect()),
    );
    let mut f = std::fs::File::create(path)?;
    f.write_all(Json::Obj(root).to_string().as_bytes())?;
    writeln!(f)?;
    Ok(())
}

/// A parsed bench artifact: measurements plus optional metadata. Raw
/// [`Bench::write_json`] output is a bare array; committed baselines wrap
/// it as `{"meta": {"provenance": ...}, "results": [...]}` so the
/// regression gate knows whether the numbers were actually measured.
pub struct BenchArtifact {
    pub results: Vec<Measurement>,
    /// `meta.provenance` when present (`"measured"` arms the CI gate;
    /// `"desk-estimate"` keeps it warn-only until refreshed on real
    /// hardware). A bare array counts as measured.
    pub provenance: Option<String>,
}

impl BenchArtifact {
    /// `true` unless the artifact explicitly declares itself an estimate.
    pub fn is_measured(&self) -> bool {
        self.provenance.as_deref().map(|p| p == "measured").unwrap_or(true)
    }
}

/// Read a `BENCH_*.json` artifact (bare array or `{meta, results}` form).
pub fn read_json_artifact(path: &str) -> crate::util::error::Result<BenchArtifact> {
    use crate::err;
    use crate::util::error::Context;
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).map_err(|e| err!("{path}: {e}"))?;
    let (items, provenance) = match &j {
        Json::Arr(v) => (v.as_slice(), None),
        Json::Obj(_) => {
            let items = j
                .get("results")
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("{path}: object artifact needs a 'results' array"))?;
            let prov = j
                .at(&["meta", "provenance"])
                .and_then(Json::as_str)
                .map(str::to_string);
            (items, prov)
        }
        _ => return Err(err!("{path}: expected array or object artifact")),
    };
    let mut results = Vec::with_capacity(items.len());
    for m in items {
        let field = |k: &str| m.get(k).and_then(Json::as_f64);
        results.push(Measurement {
            name: m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| err!("{path}: measurement without 'name'"))?
                .to_string(),
            iters: m.get("iters").and_then(Json::as_usize).unwrap_or(0),
            mean_ns: field("mean_ns").unwrap_or(0.0),
            stddev_ns: field("stddev_ns").unwrap_or(0.0),
            median_ns: field("median_ns")
                .ok_or_else(|| err!("{path}: measurement without 'median_ns'"))?,
            p10_ns: field("p10_ns").unwrap_or(0.0),
            p90_ns: field("p90_ns").unwrap_or(0.0),
        });
    }
    Ok(BenchArtifact { results, provenance })
}

/// One baseline/current pair in a regression check.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// `cur / base`, divided by the host-speed scale in normalized mode —
    /// 1.0 means unchanged, 2.0 means a 2× slowdown.
    pub ratio: f64,
}

/// Fewest comparable benches for which host-speed normalization is
/// trustworthy: below this the median ratio is dominated by the very
/// benches it should judge (a lone survivor would always normalize its own
/// regression away), so [`compare_benches`] falls back to absolute mode.
pub const MIN_ROWS_TO_NORMALIZE: usize = 3;

/// Result of comparing two bench artifacts by median latency.
pub struct BenchComparison {
    pub rows: Vec<BenchDelta>,
    /// Baseline bench names (above the noise floor) with no counterpart in
    /// the current artifact — renamed or deleted benches. Surfaced in the
    /// report so a silently un-gated path is visible.
    pub missing: Vec<String>,
    /// Host-speed factor divided out of every ratio (1.0 in absolute mode):
    /// the median of the raw `cur/base` ratios. Makes the gate portable
    /// across runner generations — a uniformly faster machine doesn't mask
    /// one bench regressing relative to the rest, and a uniformly slower
    /// one doesn't flag everything. The flip side — a regression broad
    /// enough to move the *median* also moves the scale — is why the report
    /// prints the scale and warns when it drifts far from 1.0.
    pub scale: f64,
    /// Regression threshold as a fraction (0.25 = fail beyond +25%).
    pub threshold: f64,
}

impl BenchComparison {
    /// Benches whose (normalized) median regressed beyond the threshold.
    pub fn regressions(&self) -> Vec<&BenchDelta> {
        self.rows.iter().filter(|r| r.ratio > 1.0 + self.threshold).collect()
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "bench-check: {} benches, host scale {:.3}, threshold +{:.0}%\n",
            self.rows.len(),
            self.scale,
            self.threshold * 100.0
        );
        for r in &self.rows {
            let flag = if r.ratio > 1.0 + self.threshold { "  REGRESSED" } else { "" };
            out.push_str(&format!(
                "{:<52} {:>12} -> {:>12}  x{:.3}{}\n",
                r.name,
                Measurement::fmt_ns(r.base_ns),
                Measurement::fmt_ns(r.cur_ns),
                r.ratio,
                flag
            ));
        }
        for name in &self.missing {
            out.push_str(&format!(
                "{name:<52} MISSING from current artifact — this path is NOT gated\n"
            ));
        }
        if !(0.77..=1.3).contains(&self.scale) {
            out.push_str(&format!(
                "warning: host scale {:.3} is far from 1.0 — either the runner changed, or a \
                 regression broad enough to move the median is being normalized away; \
                 cross-check with --absolute\n",
                self.scale
            ));
        }
        out
    }
}

/// Compare `cur` against `base` by bench name over their shared benches,
/// ignoring entries whose baseline median sits below `min_ns` (noise
/// floor). `normalize` divides out the median `cur/base` ratio so only
/// *relative* regressions (one path slowing down vs the rest) trip the
/// gate; pass `false` for strict same-host absolute comparison. With fewer
/// than [`MIN_ROWS_TO_NORMALIZE`] comparable benches, normalization is
/// skipped (see the constant's docs).
pub fn compare_benches(
    base: &[Measurement],
    cur: &[Measurement],
    threshold: f64,
    min_ns: f64,
    normalize: bool,
) -> BenchComparison {
    let mut rows: Vec<BenchDelta> = Vec::new();
    let mut missing: Vec<String> = Vec::new();
    for b in base.iter().filter(|b| b.median_ns >= min_ns) {
        match cur.iter().find(|c| c.name == b.name) {
            Some(c) => rows.push(BenchDelta {
                name: b.name.clone(),
                base_ns: b.median_ns,
                cur_ns: c.median_ns,
                ratio: c.median_ns / b.median_ns.max(1e-9),
            }),
            None => missing.push(b.name.clone()),
        }
    }
    let scale = if normalize && rows.len() >= MIN_ROWS_TO_NORMALIZE {
        let mut ratios: Vec<f64> = rows.iter().map(|r| r.ratio).collect();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // true median (middle-pair average on even counts): taking the
        // upper element would let a lone regression among two survivors
        // set the scale and normalize itself away
        let mid = ratios.len() / 2;
        let median = if ratios.len() % 2 == 0 {
            (ratios[mid - 1] + ratios[mid]) / 2.0
        } else {
            ratios[mid]
        };
        median.max(1e-9)
    } else {
        1.0
    };
    for r in &mut rows {
        r.ratio /= scale;
    }
    BenchComparison { rows, missing, scale, threshold }
}

/// Create `path`'s parent directory if needed (report emitters write into
/// `reports/`, which a fresh checkout doesn't have).
fn ensure_parent_dir(path: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// `true` when `--quick` appears in the bench args, or `SF_BENCH_QUICK=1` /
/// `BENCH_QUICK=1` is set in the environment (the CI bench-smoke knob).
pub fn quick_requested() -> bool {
    let env_quick = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    std::env::args().any(|a| a == "--quick")
        || env_quick("SF_BENCH_QUICK")
        || env_quick("BENCH_QUICK")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick().with_budget(Duration::from_millis(30));
        let m = b.run("spin", || (0..1000u64).sum::<u64>());
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn percentiles_ordered() {
        let mut b = Bench::quick().with_budget(Duration::from_millis(30));
        let m = b.run("spin2", || (0..5000u64).product::<u64>()).clone();
        assert!(m.p10_ns <= m.median_ns + 1.0);
        assert!(m.median_ns <= m.p90_ns + 1.0);
    }

    #[test]
    fn record_passthrough() {
        let mut b = Bench::quick();
        b.record("ext", Duration::from_millis(10), 10);
        assert_eq!(b.results().len(), 1);
        assert!((b.results()[0].mean_ns - 1e6).abs() < 1.0);
    }

    fn meas(name: &str, median_ns: f64) -> Measurement {
        Measurement {
            name: name.into(),
            iters: 10,
            mean_ns: median_ns,
            stddev_ns: 0.0,
            median_ns,
            p10_ns: median_ns,
            p90_ns: median_ns,
        }
    }

    #[test]
    fn injected_2x_slowdown_fails_the_gate() {
        // The bench-regression gate's core demonstration: same numbers pass,
        // doubling one bench's median fails — in normalized and absolute
        // mode both.
        let base = vec![meas("e2e/a", 1e6), meas("e2e/b", 2e6), meas("e2e/c", 4e6)];
        let mut cur = base.clone();
        for normalize in [true, false] {
            let ok = compare_benches(&base, &cur, 0.25, 0.0, normalize);
            assert!(ok.regressions().is_empty(), "clean run must pass");
        }
        cur[1].median_ns *= 2.0; // inject the slowdown
        for normalize in [true, false] {
            let bad = compare_benches(&base, &cur, 0.25, 0.0, normalize);
            let regs = bad.regressions();
            assert_eq!(regs.len(), 1, "normalize={normalize}");
            assert_eq!(regs[0].name, "e2e/b");
            assert!(bad.report().contains("REGRESSED"));
        }
    }

    #[test]
    fn normalization_absorbs_uniform_host_speed() {
        // A uniformly 1.6× slower host is a machine difference, not a
        // regression: normalized mode passes, absolute mode (same-host
        // comparisons) flags everything.
        let base = vec![meas("a", 1e6), meas("b", 2e6), meas("c", 3e6)];
        let cur: Vec<Measurement> = base.iter().map(|m| meas(&m.name, m.median_ns * 1.6)).collect();
        let norm = compare_benches(&base, &cur, 0.25, 0.0, true);
        assert!(norm.regressions().is_empty());
        assert!((norm.scale - 1.6).abs() < 1e-9);
        let abs = compare_benches(&base, &cur, 0.25, 0.0, false);
        assert_eq!(abs.regressions().len(), 3);
    }

    #[test]
    fn few_survivors_fall_back_to_absolute() {
        // Below MIN_ROWS_TO_NORMALIZE the median is dominated by the very
        // benches it should judge (a lone survivor would always normalize
        // its own regression away) — so with 2 rows the gate compares
        // absolutely and the 2× slowdown still trips it.
        let base = vec![meas("a", 1e6), meas("b", 1e6)];
        let cur = vec![meas("a", 1e6), meas("b", 2e6)];
        let cmp = compare_benches(&base, &cur, 0.25, 0.0, true);
        assert_eq!(cmp.scale, 1.0, "normalization must be skipped under the row minimum");
        let regs = cmp.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "b");
        // single survivor: same story
        let cmp1 = compare_benches(&base[1..], &cur[1..], 0.25, 0.0, true);
        assert_eq!(cmp1.scale, 1.0);
        assert_eq!(cmp1.regressions().len(), 1);
    }

    #[test]
    fn even_count_median_splits_the_middle_pair() {
        // 4 rows, one regressed 2×: sorted ratios [1, 1, 1, 2] → scale
        // (1+1)/2 = 1.0, not the upper middle element — the regression
        // can't drag the scale toward itself.
        let base = vec![meas("a", 1e6), meas("b", 1e6), meas("c", 1e6), meas("d", 1e6)];
        let mut cur = base.clone();
        cur[3].median_ns = 2e6;
        let cmp = compare_benches(&base, &cur, 0.25, 0.0, true);
        assert_eq!(cmp.scale, 1.0);
        assert_eq!(cmp.regressions().len(), 1);
        assert_eq!(cmp.regressions()[0].name, "d");
    }

    #[test]
    fn missing_benches_are_reported_not_dropped() {
        // A renamed/deleted bench must show up in the report as un-gated,
        // not vanish silently.
        let base = vec![meas("kept", 1e6), meas("gone", 1e6), meas("tiny-gone", 1e3)];
        let cur = vec![meas("kept", 1e6)];
        let cmp = compare_benches(&base, &cur, 0.25, 50_000.0, true);
        assert_eq!(cmp.rows.len(), 1);
        // "tiny-gone" sits below the noise floor — never tracked at all
        assert_eq!(cmp.missing, vec!["gone".to_string()]);
        assert!(cmp.report().contains("MISSING"));
        assert!(cmp.report().contains("gone"));
    }

    #[test]
    fn scale_drift_warns_in_report() {
        // A uniform 1.6× slowdown normalizes away (by design) but the
        // report must call the drifted scale out for cross-checking.
        let base = vec![meas("a", 1e6), meas("b", 2e6), meas("c", 3e6)];
        let cur: Vec<Measurement> =
            base.iter().map(|m| meas(&m.name, m.median_ns * 1.6)).collect();
        let cmp = compare_benches(&base, &cur, 0.25, 0.0, true);
        assert!(cmp.regressions().is_empty());
        assert!(cmp.report().contains("warning: host scale"));
        // near-1.0 scale stays quiet
        let quiet = compare_benches(&base, &base, 0.25, 0.0, true);
        assert!(!quiet.report().contains("warning"));
    }

    #[test]
    fn noise_floor_and_disjoint_names() {
        let base = vec![meas("tiny", 1e3), meas("big", 1e7)];
        let cur = vec![meas("tiny", 5e3), meas("big", 1e7), meas("new", 1e6)];
        // "tiny" is below the 50µs floor: ignored even at 5× slower;
        // "new" has no baseline: ignored
        let cmp = compare_benches(&base, &cur, 0.25, 50_000.0, false);
        assert_eq!(cmp.rows.len(), 1);
        assert!(cmp.regressions().is_empty());
    }

    #[test]
    fn artifact_forms_parse_and_carry_provenance() {
        let dir = std::env::temp_dir();
        // bare array (what write_json emits) counts as measured
        let raw = dir.join("BENCH_check_raw_test.json");
        let mut b = Bench::quick();
        b.record("e2e/x", Duration::from_millis(3), 3);
        b.write_json(raw.to_str().unwrap()).unwrap();
        let a = read_json_artifact(raw.to_str().unwrap()).unwrap();
        assert!(a.is_measured());
        assert_eq!(a.results.len(), 1);
        assert_eq!(a.results[0].name, "e2e/x");
        // wrapped object with desk-estimate provenance disarms the gate
        let wrapped = dir.join("BENCH_check_wrapped_test.json");
        std::fs::write(
            &wrapped,
            r#"{"meta": {"provenance": "desk-estimate"},
                "results": [{"name": "e2e/x", "median_ns": 1000.0}]}"#,
        )
        .unwrap();
        let w = read_json_artifact(wrapped.to_str().unwrap()).unwrap();
        assert!(!w.is_measured());
        assert_eq!(w.results[0].median_ns, 1000.0);
        // junk is a parse error, not a panic
        let junk = dir.join("BENCH_check_junk_test.json");
        std::fs::write(&junk, "not json").unwrap();
        assert!(read_json_artifact(junk.to_str().unwrap()).is_err());
        for p in [raw, wrapped, junk] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn measured_baseline_writes_armed_artifact() {
        // --update-baseline's core contract: the written file parses as a
        // wrapped artifact with provenance=measured, which arms the gate.
        let path = std::env::temp_dir().join("BENCH_baseline_update_test.json");
        let path = path.to_str().unwrap().to_string();
        let results = vec![meas("e2e/x", 2e6), meas("e2e/y", 5e6)];
        write_measured_baseline(&path, &results, "unit test").unwrap();
        let a = read_json_artifact(&path).unwrap();
        assert!(a.is_measured(), "refreshed baseline must arm the gate");
        assert_eq!(a.provenance.as_deref(), Some("measured"));
        assert_eq!(a.results.len(), 2);
        assert_eq!(a.results[1].name, "e2e/y");
        assert_eq!(a.results[1].median_ns, 5e6);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_artifact_roundtrips() {
        use crate::util::json::Json;
        let mut b = Bench::quick();
        b.record("e2e/x", Duration::from_millis(2), 4);
        b.record("e2e/y", Duration::from_millis(1), 2);
        let path = std::env::temp_dir().join("BENCH_bench_unit_test.json");
        let path = path.to_str().unwrap().to_string();
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        match parsed {
            Json::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[0].get("name"), Some(&Json::Str("e2e/x".into())));
                assert!(v[0].get("mean_ns").and_then(|j| j.as_f64()).unwrap() > 0.0);
            }
            other => panic!("expected array, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
