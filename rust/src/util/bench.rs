//! Micro-benchmark harness (offline substitute for `criterion`).
//!
//! All `cargo bench` targets in `rust/benches/` are `harness = false`
//! binaries built on this module. The methodology mirrors criterion's core:
//! warmup, then timed iterations, reporting median / p10 / p90 and
//! mean±stddev. Results are printed as aligned text and optionally appended
//! to a CSV so EXPERIMENTS.md can cite exact numbers.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<52} {:>12} (median {:>12}, p10 {:>12}, p90 {:>12}, n={})",
            self.name,
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.median_ns),
            Self::fmt_ns(self.p10_ns),
            Self::fmt_ns(self.p90_ns),
            self.iters,
        )
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{:.1},{:.1},{:.1},{:.1}",
            self.name, self.iters, self.mean_ns, self.stddev_ns,
            self.median_ns, self.p10_ns, self.p90_ns
        )
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 1000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI-ish runs (shorter budget).
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_iters: 3,
            max_iters: 200,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run `f` repeatedly, using its return value to defeat dead-code elim.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup: also estimates per-iteration cost.
        let wstart = Instant::now();
        let mut wit = 0usize;
        while wstart.elapsed() < self.warmup || wit == 0 {
            std::hint::black_box(f());
            wit += 1;
            if wit >= self.max_iters {
                break;
            }
        }
        let est = wstart.elapsed().as_secs_f64() / wit as f64;
        let target = (self.budget.as_secs_f64() / est.max(1e-9)) as usize;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally measured value (e.g. a one-shot end-to-end run).
    pub fn record(&mut self, name: &str, elapsed: Duration, iters: usize) {
        let ns = elapsed.as_nanos() as f64 / iters.max(1) as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ns: ns,
            stddev_ns: 0.0,
            median_ns: ns,
            p10_ns: ns,
            p90_ns: ns,
        };
        println!("{}", m.report());
        self.results.push(m);
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Append all results to a CSV file (with header if new).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        ensure_parent_dir(path)?;
        let new = !std::path::Path::new(path).exists();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "name,iters,mean_ns,stddev_ns,median_ns,p10_ns,p90_ns")?;
        }
        for m in &self.results {
            writeln!(f, "{}", m.csv_row())?;
        }
        Ok(())
    }

    /// Overwrite `path` with all results as a JSON array — the
    /// machine-readable bench artifact CI uploads (`BENCH_*.json`).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        use std::io::Write;
        ensure_parent_dir(path)?;
        let arr = Json::Arr(
            self.results
                .iter()
                .map(|m| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(m.name.clone()));
                    o.insert("iters".to_string(), Json::Num(m.iters as f64));
                    o.insert("mean_ns".to_string(), Json::Num(m.mean_ns));
                    o.insert("stddev_ns".to_string(), Json::Num(m.stddev_ns));
                    o.insert("median_ns".to_string(), Json::Num(m.median_ns));
                    o.insert("p10_ns".to_string(), Json::Num(m.p10_ns));
                    o.insert("p90_ns".to_string(), Json::Num(m.p90_ns));
                    Json::Obj(o)
                })
                .collect(),
        );
        let text = arr.to_string();
        let mut f = std::fs::File::create(path)?;
        f.write_all(text.as_bytes())?;
        writeln!(f)?;
        Ok(())
    }
}

/// Create `path`'s parent directory if needed (report emitters write into
/// `reports/`, which a fresh checkout doesn't have).
fn ensure_parent_dir(path: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// `true` when `--quick` appears in the bench args, or `SF_BENCH_QUICK=1` /
/// `BENCH_QUICK=1` is set in the environment (the CI bench-smoke knob).
pub fn quick_requested() -> bool {
    let env_quick = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    std::env::args().any(|a| a == "--quick")
        || env_quick("SF_BENCH_QUICK")
        || env_quick("BENCH_QUICK")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick().with_budget(Duration::from_millis(30));
        let m = b.run("spin", || (0..1000u64).sum::<u64>());
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn percentiles_ordered() {
        let mut b = Bench::quick().with_budget(Duration::from_millis(30));
        let m = b.run("spin2", || (0..5000u64).product::<u64>()).clone();
        assert!(m.p10_ns <= m.median_ns + 1.0);
        assert!(m.median_ns <= m.p90_ns + 1.0);
    }

    #[test]
    fn record_passthrough() {
        let mut b = Bench::quick();
        b.record("ext", Duration::from_millis(10), 10);
        assert_eq!(b.results().len(), 1);
        assert!((b.results()[0].mean_ns - 1e6).abs() < 1.0);
    }

    #[test]
    fn json_artifact_roundtrips() {
        use crate::util::json::Json;
        let mut b = Bench::quick();
        b.record("e2e/x", Duration::from_millis(2), 4);
        b.record("e2e/y", Duration::from_millis(1), 2);
        let path = std::env::temp_dir().join("BENCH_bench_unit_test.json");
        let path = path.to_str().unwrap().to_string();
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        match parsed {
            Json::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[0].get("name"), Some(&Json::Str("e2e/x".into())));
                assert!(v[0].get("mean_ns").and_then(|j| j.as_f64()).unwrap() > 0.0);
            }
            other => panic!("expected array, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
