//! Deterministic pseudo-random numbers (offline substitute for `rand`).
//!
//! [`Pcg32`] is the PCG-XSH-RR generator (Melissa O'Neill, 2014): 64-bit
//! state, 32-bit output, excellent statistical quality for simulation work.
//! Seeding goes through SplitMix64 so that small consecutive seeds produce
//! uncorrelated streams — every experiment in this repo is reproducible from
//! a single `u64` seed recorded in EXPERIMENTS.md.

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Spare Box–Muller output (the sin branch) — [`Self::normal`] produces
    /// two normals per transcendental bundle; caching the second halves the
    /// cost of bulk weight generation.
    spare_normal: Option<f32>,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 step — used to derive seed material.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a seed; distinct seeds give distinct streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // stream selector must be odd
        let mut rng = Pcg32 { state: 0, inc: init_inc, spare_normal: None };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (both branches used; see
    /// `spare_normal`).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Vector of standard normals (weight initialization).
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg32::new(0);
        let mut b = Pcg32::new(1);
        let same = (0..1000).filter(|_| a.next_u32() == b.next_u32()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::new(5);
        for _ in 0..50 {
            let s = r.sample_indices(64, 16);
            assert_eq!(s.len(), 16);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 16);
            assert!(d.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Pcg32::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }
}
