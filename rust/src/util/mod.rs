//! Offline-environment substrates.
//!
//! The baked cargo registry only carries the `xla` crate closure, so the
//! usual ecosystem crates (rand, serde, clap, criterion, proptest) are
//! unavailable. Each submodule is a small, tested, from-scratch replacement
//! for exactly the slice of functionality this project needs:
//!
//! * [`rng`] — SplitMix64 + PCG32, uniform/normal/shuffle (replaces `rand`).
//! * [`json`] — minimal JSON parse/serialize for `artifacts/manifest.json`
//!   and report emission (replaces `serde_json`).
//! * [`error`] — string-carrying `Error`/`Result` + `err!` macro + `Context`
//!   combinators (replaces `anyhow` on the offline-core path).
//! * [`bench`] — warmup/iteration timing harness with percentiles
//!   (replaces `criterion`; used by all `cargo bench` targets).
//! * [`check`] — mini property-testing: seeded generators + `forall` with
//!   failing-seed reporting (replaces `proptest`).
//! * [`cli`] — tiny flag parser for the `spectral-flow` binary and the
//!   examples (replaces `clap`).

pub mod bench;
pub mod check;
pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
