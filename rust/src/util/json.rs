//! Minimal JSON parser + serializer (offline substitute for `serde_json`).
//!
//! Covers exactly what this project needs: parsing `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null; `\uXXXX` escapes) and
//! emitting report JSON/CSV payloads. Numbers are held as `f64`, which is
//! lossless for every integer the manifest carries (< 2^53).
//!
//! Since the `net` subsystem landed, this parser also consumes bytes from
//! the wire, so it is hardened against untrusted input: every parse runs
//! under a [`JsonLimits`] budget — a maximum input size (checked before a
//! single byte is scanned) and a recursion-depth cap (checked at every
//! nested value, so `[[[[…` cannot overflow the stack). [`Json::parse`]
//! applies generous defaults sized for local artifacts; network callers
//! pass their own tighter budget via [`Json::parse_with_limits`].

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse budget for untrusted input (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JsonLimits {
    /// Maximum input length in bytes; longer inputs are rejected before
    /// any scanning happens.
    pub max_bytes: usize,
    /// Maximum nesting depth of arrays/objects (the top-level value sits at
    /// depth 1). Bounds parser recursion, so hostile `[[[[…` input errors
    /// out instead of overflowing the stack.
    pub max_depth: usize,
}

impl Default for JsonLimits {
    /// Generous defaults for trusted local artifacts (manifests, bench
    /// JSON): 256 MiB, depth 128. Network callers should pass something
    /// far tighter (the HTTP layer uses its body cap and depth 32).
    fn default() -> Self {
        JsonLimits { max_bytes: 256 << 20, max_depth: 128 }
    }
}

impl Json {
    /// Parse with the default (local-artifact) limits.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        Self::parse_with_limits(src, JsonLimits::default())
    }

    /// Parse under an explicit [`JsonLimits`] budget — the entry point for
    /// bytes that arrived over the network.
    pub fn parse_with_limits(src: &str, limits: JsonLimits) -> Result<Json, JsonError> {
        if src.len() > limits.max_bytes {
            return Err(JsonError {
                offset: limits.max_bytes,
                message: format!(
                    "input too large: {} bytes (limit {})",
                    src.len(),
                    limits.max_bytes
                ),
            });
        }
        let mut p = Parser { src: src.as_bytes(), pos: 0, depth: 0, max_depth: limits.max_depth };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-ish None on any miss.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // -0.0 must not collapse to integer "0": the net layer's
                // bit-exact round-trip contract keeps the sign bit
                if n.fract() == 0.0 && n.abs() < 9e15 && !(*n == 0.0 && n.is_sign_negative()) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len()
            && matches!(self.src[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        // Depth accounting here (the single recursion point) covers both
        // containers; scalars enter and leave at the same depth.
        self.depth += 1;
        if self.depth > self.max_depth {
            self.depth -= 1;
            return Err(self.err(&format!("nesting deeper than {} levels", self.max_depth)));
        }
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .src
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf-8: copy raw bytes of the char
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.src[start..])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x", "c": false}], "d": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        let j = Json::Num(-0.0);
        assert_eq!(j.to_string(), "-0");
        let back = Json::parse(&j.to_string()).unwrap();
        match back {
            Json::Num(n) => assert!(n == 0.0 && n.is_sign_negative()),
            other => panic!("expected number, got {other:?}"),
        }
        // positive zero still serializes as the plain integer
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"fft_size":8,"layers":[{"cin":3,"name":"conv1_1","pool":true}],"x":null}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
        // raw multi-byte utf-8 passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn depth_limit_rejects_deep_nesting_without_overflow() {
        // 100k unclosed arrays: with unbounded recursion this would blow
        // the stack long before hitting the "unexpected end" error; the
        // depth cap must turn it into an ordinary parse error.
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.message.contains("nesting"), "{err}");
        // mixed array/object nesting hits the same cap
        let mixed = "[{\"k\":".repeat(50_000);
        assert!(Json::parse(&mixed).is_err());
        // a document exactly at the cap still parses
        let limits = JsonLimits { max_depth: 8, ..JsonLimits::default() };
        let ok = "[[[[[[[1]]]]]]]"; // depth 8 (7 arrays + the number)
        assert!(Json::parse_with_limits(ok, limits).is_ok());
        let too_deep = "[[[[[[[[1]]]]]]]]"; // depth 9
        assert!(Json::parse_with_limits(too_deep, limits).is_err());
    }

    #[test]
    fn size_limit_rejects_before_scanning() {
        let limits = JsonLimits { max_bytes: 16, ..JsonLimits::default() };
        assert!(Json::parse_with_limits("[1,2,3]", limits).is_ok());
        let big = format!("[{}]", "1,".repeat(100));
        let err = Json::parse_with_limits(&big, limits).unwrap_err();
        assert!(err.message.contains("too large"), "{err}");
        // default limits are generous enough for any artifact this repo emits
        assert!(Json::parse(&format!("[{}1]", "1,".repeat(1000))).is_ok());
    }

    #[test]
    fn builders_serialize() {
        let j = obj(vec![
            ("name", s("vgg")),
            ("n", num(64.0)),
            ("xs", arr(vec![num(1.0), num(2.0)])),
        ]);
        assert_eq!(j.to_string(), r#"{"n":64,"name":"vgg","xs":[1,2]}"#);
    }
}
