//! Minimal error type (offline substitute for `anyhow`).
//!
//! The offline-core path (interp backend, manifest parsing, the coordinator
//! server) needs nothing fancier than a string-carrying error that threads
//! through `?`, crosses channels (`Send`), and prints well from `main`. The
//! [`err!`] macro mirrors `anyhow!`, and the [`Context`] trait mirrors the
//! `.context(..)` / `.with_context(..)` combinators on both `Result` and
//! `Option`.
//!
//! ```
//! use spectral_flow::err;
//! use spectral_flow::util::error::{Context, Result};
//!
//! fn parse(s: &str) -> Result<usize> {
//!     s.parse::<usize>()
//!         .map_err(|e| err!("bad count {s:?}: {e}"))?
//!         .checked_mul(2)
//!         .context("count overflows")
//! }
//! assert!(parse("21").is_ok());
//! assert!(parse("x").is_err());
//! ```

use std::fmt;

/// A string-carrying error. Construct with [`Error::msg`] or the [`err!`]
/// macro (`crate::err!` / `spectral_flow::err!`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error { msg: e.to_string() }
    }
}

impl From<std::sync::mpsc::RecvError> for Error {
    fn from(_: std::sync::mpsc::RecvError) -> Self {
        Error { msg: "channel sender dropped".to_string() }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (the `E` default lets signatures stay short).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style combinators for `Result` and `Option`.
pub trait Context<T> {
    /// Replace/augment the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Replace/augment the error with a lazily built message.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.into()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`-style formatted error constructor.
///
/// Exported at the crate root (`use spectral_flow::err;`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e}"), "boom");
    }

    #[test]
    fn macro_formats() {
        let e = crate::err!("bad shape {:?} at layer {}", [1, 2], "conv1");
        assert!(e.to_string().contains("[1, 2]"));
        assert!(e.to_string().contains("conv1"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while rendering").unwrap_err();
        assert!(e.to_string().starts_with("while rendering: "));

        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let o2: Option<u8> = Some(3);
        assert_eq!(o2.with_context(|| "unused".into()).unwrap(), 3);
    }

    #[test]
    fn converts_io_and_json_errors() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        let je = crate::util::json::Json::parse("{").unwrap_err();
        let e2: Error = je.into();
        assert!(e2.to_string().contains("json error"));
    }

    #[test]
    fn error_is_send_and_static() {
        fn assert_send<T: Send + 'static>() {}
        assert_send::<Error>();
    }
}
