//! Sparse spectral kernels.
//!
//! A "kernel" here is one (output-channel, input-channel) K×K spectral
//! plane pruned to `K²/α` non-zeros (paper §4: uniform compression ratio α
//! across kernels, following the ADMM method of [16]). The *index pattern*
//! is what the scheduling algorithm (paper Alg. 2) consumes; the values are
//! what the numerics path consumes (as dense planes with explicit zeros).
//!
//! Two generators reproduce the paper's two evaluation regimes:
//!
//! * [`prune_magnitude`] — "ADMM-like": top K²/α indices of a synthetic
//!   trained-kernel energy model (shared low-frequency field + per-kernel
//!   jitter), giving the clustered, cross-correlated patterns the paper
//!   observes in conv5_* (where lowest-index-first scheduling does well).
//! * [`prune_random`] — uniform random index choice (paper Fig. 10:
//!   "generate sparse kernels ... by randomly choose K²/α non-zero weights").

use crate::fft::tiles_per_side;
use crate::tensor::ComplexTensor;
use crate::util::rng::Pcg32;

/// One sparse spectral kernel: sorted frequency indices (0..K²) + values.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseKernel {
    /// Sorted, distinct indices into the flattened K×K frequency plane.
    pub indices: Vec<u16>,
    /// Complex values matching `indices` (re, im).
    pub values: Vec<(f32, f32)>,
}

impl SparseKernel {
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    fn assert_valid(&self, k2: usize) {
        assert_eq!(self.indices.len(), self.values.len());
        for w in self.indices.windows(2) {
            assert!(w[0] < w[1], "indices must be sorted+distinct");
        }
        if let Some(&last) = self.indices.last() {
            assert!((last as usize) < k2, "index {last} out of K²={k2}");
        }
    }
}

/// All sparse kernels of one conv layer, indexed `[cout][cin]`.
#[derive(Debug, Clone)]
pub struct SparseLayer {
    pub cout: usize,
    pub cin: usize,
    pub fft: usize,
    /// Row-major `[cout][cin]`.
    pub kernels: Vec<SparseKernel>,
    /// Compression ratio α (K²/α non-zeros per kernel).
    pub alpha: usize,
}

impl SparseLayer {
    pub fn kernel(&self, n: usize, m: usize) -> &SparseKernel {
        &self.kernels[n * self.cin + m]
    }

    pub fn k2(&self) -> usize {
        self.fft * self.fft
    }

    pub fn nnz_per_kernel(&self) -> usize {
        self.k2() / self.alpha
    }

    /// Total non-zeros across the layer.
    pub fn total_nnz(&self) -> u64 {
        self.kernels.iter().map(|k| k.nnz() as u64).sum()
    }

    /// Dense spectral planes `[cout, cin, K, K]` (re, im) for the AOT
    /// executables — pruned positions carry explicit zeros.
    pub fn to_dense_planes(&self) -> ComplexTensor {
        let k2 = self.k2();
        let shape = [self.cout, self.cin, self.fft, self.fft];
        let mut out = ComplexTensor::zeros(&shape);
        for n in 0..self.cout {
            for m in 0..self.cin {
                let k = self.kernel(n, m);
                for (&idx, &(re, im)) in k.indices.iter().zip(&k.values) {
                    let (y, x) = ((idx as usize) / self.fft, (idx as usize) % self.fft);
                    out.set(&[n, m, y, x], re, im);
                }
            }
        }
        debug_assert_eq!(out.len(), shape.iter().product::<usize>());
        let _ = k2;
        out
    }

    /// Index sets of one *kernel group*: the N' kernels `{W[n, m]}` for
    /// `n ∈ [group·n_par, ..)` at fixed input channel `m` — the scheduling
    /// instance of paper Alg. 2 (M' = 1: channels are serial, §5.1).
    pub fn group_indices(&self, group: usize, n_par: usize, m: usize) -> Vec<Vec<u16>> {
        let start = group * n_par;
        let end = (start + n_par).min(self.cout);
        (start..end)
            .map(|n| self.kernel(n, m).indices.clone())
            .collect()
    }

    pub fn num_groups(&self, n_par: usize) -> usize {
        self.cout.div_ceil(n_par)
    }

    fn assert_valid(&self) {
        assert_eq!(self.kernels.len(), self.cout * self.cin);
        let k2 = self.k2();
        for k in &self.kernels {
            k.assert_valid(k2);
        }
    }
}

/// "ADMM-like" pruning: keep the top K²/α indices of an energy model that
/// mimics trained-then-ADMM-pruned spectral kernels.
///
/// An i.i.d.-random spatial kernel has a *flat* expected spectrum, so
/// naively FFT-ing random weights gives no clustering at all (we measured
/// it). Trained kernels are smooth: their spectral energy decays with the
/// wrapped frequency radius, and kernels within a layer share structure —
/// which is exactly why the paper's lowest-index-first baseline does well
/// on conv5_2/conv5_3 ("indices in different kernels are close"). We model
/// both properties directly:
///
/// * a per-layer shared energy field `exp(-r²(f)/2σ²) · lognormal jitter`
///   (σ = K/3.6, calibrated so exact-cover utilization at the paper's
///   operating points matches Fig. 9 — see EXPERIMENTS.md §Calibration), and
/// * per-kernel lognormal jitter controlling cross-kernel correlation.
///
/// Each kernel keeps its top K²/α indices by `shared · individual` score;
/// values are complex normals scaled by the field (energy-consistent).
pub fn prune_magnitude(
    cout: usize,
    cin: usize,
    fft: usize,
    alpha: usize,
    rng: &mut Pcg32,
) -> SparseLayer {
    let k2 = fft * fft;
    let nnz = k2 / alpha;
    assert!(nnz >= 1, "alpha {alpha} prunes everything at K={fft}");
    let sigma2 = (fft as f64 / 3.6).powi(2);
    // shared layer field: smooth low-frequency decay × mild jitter
    let shared: Vec<f64> = (0..k2)
        .map(|i| {
            let (y, x) = (i / fft, i % fft);
            let fy = y.min(fft - y) as f64;
            let fx = x.min(fft - x) as f64;
            let r2 = fy * fy + fx * fx;
            (-r2 / (2.0 * sigma2)).exp() * (rng.normal() as f64 * 0.35).exp()
        })
        .collect();
    let vscale = (1.0 / (cin * nnz) as f32).sqrt();
    // Per-kernel jitter is the hot loop (K² draws × cout·cin kernels — a
    // conv5 layer alone needs ~17M lognormals). A 4096-entry pool sampled
    // by the PCG stream preserves the distribution for pattern purposes at
    // ~6× the speed (§Perf L3, EXPERIMENTS.md).
    let jitter_pool: Vec<f64> =
        (0..4096).map(|_| (rng.normal() as f64 * 0.5).exp()).collect();
    let mut kernels = Vec::with_capacity(cout * cin);
    let mut scores: Vec<(f64, u16)> = Vec::with_capacity(k2);
    for _ in 0..cout * cin {
        scores.clear();
        for (i, &s) in shared.iter().enumerate() {
            let jitter = jitter_pool[(rng.next_u32() & 4095) as usize];
            scores.push((s * jitter, i as u16));
        }
        // top-nnz selection in O(K²) (hot path: 512×512 kernels per layer)
        scores.select_nth_unstable_by(nnz - 1, |a, b| {
            b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
        });
        let mut idxs: Vec<u16> = scores[..nnz].iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        let values = idxs
            .iter()
            .map(|&i| {
                let mag = shared[i as usize].sqrt() as f32;
                (rng.normal() * vscale * mag, rng.normal() * vscale * mag)
            })
            .collect();
        kernels.push(SparseKernel { indices: idxs, values });
    }
    let layer = SparseLayer { cout, cin, fft, kernels, alpha };
    layer.assert_valid();
    layer
}

/// Random pruning: uniform K²/α index choice per kernel (paper Fig. 10).
pub fn prune_random(
    cout: usize,
    cin: usize,
    fft: usize,
    alpha: usize,
    rng: &mut Pcg32,
) -> SparseLayer {
    let k2 = fft * fft;
    let nnz = k2 / alpha;
    assert!(nnz >= 1, "alpha {alpha} prunes everything at K={fft}");
    let scale = (1.0 / (cin * nnz) as f32).sqrt();
    let mut kernels = Vec::with_capacity(cout * cin);
    for _ in 0..cout * cin {
        let mut idxs: Vec<u16> = rng
            .sample_indices(k2, nnz)
            .into_iter()
            .map(|i| i as u16)
            .collect();
        idxs.sort_unstable();
        let values = idxs
            .iter()
            .map(|_| (rng.normal() * scale, rng.normal() * scale))
            .collect();
        kernels.push(SparseKernel { indices: idxs, values });
    }
    let layer = SparseLayer { cout, cin, fft, kernels, alpha };
    layer.assert_valid();
    layer
}

/// Pattern statistics used by tests and EXPERIMENTS.md to show the two
/// generators produce the regimes the paper assumes.
///
/// Mean *wrapped* frequency radius: the DFT of a small real kernel
/// concentrates energy at low |freq|, where |freq| along each axis is the
/// circular distance min(f, K-f). Normalized so a uniform-random pattern
/// scores ≈ 0.5 and a perfectly low-frequency pattern scores ≈ 0.
pub fn index_concentration(layer: &SparseLayer) -> f64 {
    let k = layer.fft;
    let max_r = 2.0 * ((k / 2) as f64).powi(2);
    let mut sum = 0.0;
    let mut cnt = 0u64;
    for kern in &layer.kernels {
        for &i in &kern.indices {
            let (y, x) = ((i as usize) / k, (i as usize) % k);
            let fy = y.min(k - y) as f64;
            let fx = x.min(k - x) as f64;
            sum += (fy * fy + fx * fx) / max_r;
            cnt += 1;
        }
    }
    sum / cnt.max(1) as f64
}

/// Convenience: tile count of a square activation at this layer (used when
/// pairing a `SparseLayer` with a model layer for scheduling experiments).
pub fn tiles_for(h: usize, tile: usize) -> usize {
    let s = tiles_per_side(h, tile);
    s * s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn magnitude_pruning_counts() {
        let mut rng = Pcg32::new(1);
        let l = prune_magnitude(8, 4, 8, 4, &mut rng);
        assert_eq!(l.kernels.len(), 32);
        assert_eq!(l.nnz_per_kernel(), 16);
        for k in &l.kernels {
            assert_eq!(k.nnz(), 16);
        }
        assert_eq!(l.total_nnz(), 32 * 16);
    }

    #[test]
    fn random_pruning_counts_alpha8() {
        let mut rng = Pcg32::new(2);
        let l = prune_random(16, 3, 8, 8, &mut rng);
        assert_eq!(l.nnz_per_kernel(), 8);
        for k in &l.kernels {
            assert_eq!(k.nnz(), 8);
            let mut d = k.indices.clone();
            d.dedup();
            assert_eq!(d.len(), 8, "indices must be distinct");
        }
    }

    #[test]
    fn magnitude_clusters_low_frequencies() {
        // DFT of a 3x3 kernel padded to 8x8 concentrates energy at low
        // wrapped |freq|: the magnitude-pruned pattern must score clearly
        // below a uniform-random one (which sits near 0.5).
        let mut rng = Pcg32::new(3);
        let adm = prune_magnitude(32, 8, 8, 4, &mut rng);
        let rnd = prune_random(32, 8, 8, 4, &mut rng);
        let ca = index_concentration(&adm);
        let cr = index_concentration(&rnd);
        // uniform-random over the wrapped radius metric sits near 11/32 ≈
        // 0.344 at K=8 (E[min(f,K-f)²] = 5.5 per axis, max_r = 32)
        assert!(ca < cr - 0.08, "admm-like {ca} vs random {cr}");
        assert!((cr - 0.344).abs() < 0.05, "random should be ≈0.344: {cr}");
    }

    #[test]
    fn dense_planes_roundtrip() {
        let mut rng = Pcg32::new(4);
        let l = prune_random(4, 2, 8, 4, &mut rng);
        let planes = l.to_dense_planes();
        assert_eq!(planes.shape(), &[4, 2, 8, 8]);
        // every non-zero in planes appears in the sparse kernels, and counts
        // match exactly
        let mut nz = 0;
        for n in 0..4 {
            for m in 0..2 {
                for idx in 0..64 {
                    let (re, im) = planes.at(&[n, m, idx / 8, idx % 8]);
                    if re != 0.0 || im != 0.0 {
                        nz += 1;
                        assert!(l.kernel(n, m).indices.contains(&(idx as u16)));
                    }
                }
            }
        }
        assert_eq!(nz, l.total_nnz());
    }

    #[test]
    fn group_indices_cover_all_kernels() {
        forall("groups partition cout", 20, |rng| {
            let cout = rng.range(1, 100);
            let n_par = [8, 16, 32, 64][rng.range(0, 4)];
            let l = prune_random(cout, 2, 8, 4, rng);
            let groups = l.num_groups(n_par);
            let total: usize = (0..groups)
                .map(|g| l.group_indices(g, n_par, 0).len())
                .sum();
            assert_eq!(total, cout);
            // last group may be ragged but never empty
            assert!(!l.group_indices(groups - 1, n_par, 0).is_empty());
        });
    }

    #[test]
    fn generators_deterministic() {
        let a = prune_magnitude(4, 4, 8, 4, &mut Pcg32::new(9));
        let b = prune_magnitude(4, 4, 8, 4, &mut Pcg32::new(9));
        assert_eq!(a.kernels, b.kernels);
    }

    #[test]
    fn k16_supported() {
        let mut rng = Pcg32::new(5);
        let l = prune_random(4, 2, 16, 4, &mut rng);
        assert_eq!(l.nnz_per_kernel(), 64);
        assert!(l.kernels.iter().all(|k| k.indices.iter().all(|&i| i < 256)));
    }
}
