//! Model descriptions: spectral conv layer specs, the VGG16 presets the
//! paper evaluates (§6), and the activation DAG (residual adds / concats)
//! the graph presets execute. Mirrors `python/compile/model.py`; the
//! runtime cross-checks this table against `artifacts/manifest.json`.

use crate::err;
use crate::fft::TileGeometry;
use crate::util::error::Result;

/// One spectral convolutional layer (paper notation in parens).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: String,
    /// Input channels (M).
    pub cin: usize,
    /// Output channels (N).
    pub cout: usize,
    /// Input spatial side (h_in = w_in).
    pub h: usize,
    /// Spatial kernel side (k).
    pub k: usize,
    /// FFT window (K).
    pub fft: usize,
    /// 2x2 maxpool follows this layer.
    pub pool_after: bool,
}

impl ConvLayer {
    pub fn geometry(&self) -> TileGeometry {
        TileGeometry::new(self.h, self.fft, self.k)
    }

    /// Total tile count P for one image (paper: h_in*w_in / h'w').
    pub fn num_tiles(&self) -> usize {
        self.geometry().num_tiles()
    }

    /// Spectral multiply-accumulate count for one image: every (tile,
    /// cout, cin) needs K² complex MACs (paper §6.1 uses this to split the
    /// latency budget: τ_i = τ · CMP_i / CMP_total).
    pub fn spectral_macs(&self) -> u64 {
        (self.num_tiles() as u64)
            * (self.cin as u64)
            * (self.cout as u64)
            * (self.fft * self.fft) as u64
    }

    /// Spatial-domain MACs (for the complexity-reduction comparison).
    pub fn spatial_macs(&self) -> u64 {
        (self.h as u64)
            * (self.h as u64)
            * (self.cin as u64)
            * (self.cout as u64)
            * (self.k * self.k) as u64
    }

    /// Dense spectral kernel element count (the "kernel explosion").
    pub fn spectral_kernel_elems(&self) -> u64 {
        (self.cout * self.cin * self.fft * self.fft) as u64
    }
}

/// One node of a variant's activation DAG.
///
/// Tensor ids index the value stream: id 0 is the network input, node `i`
/// produces tensor `i + 1`. Nodes may only reference already-produced
/// tensors, so any well-formed node list is in topological order — a
/// "cycle" can only appear as a self/forward reference, which
/// [`check_graph`] rejects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphOp {
    /// Run conv layer `conv` (index into the layer list, including its
    /// bias/ReLU and trailing pool when `pool_after`) on tensor `input`.
    Conv { conv: usize, input: usize },
    /// Elementwise residual add of two same-shape tensors.
    Add { a: usize, b: usize },
    /// Channel-axis concat of two tensors with equal spatial side.
    Concat { a: usize, b: usize },
}

impl GraphOp {
    /// The straight-line graph every pre-DAG variant executes: layer `i`
    /// reads tensor `i` (the previous layer's output).
    pub fn chain(n_convs: usize) -> Vec<GraphOp> {
        (0..n_convs).map(|i| GraphOp::Conv { conv: i, input: i }).collect()
    }

    /// Tensor ids this node reads.
    pub fn reads(&self) -> Vec<usize> {
        match *self {
            GraphOp::Conv { input, .. } => vec![input],
            GraphOp::Add { a, b } | GraphOp::Concat { a, b } => vec![a, b],
        }
    }
}

/// The graph checker's view of one conv layer — [`ConvLayer`] and the
/// manifest's `LayerEntry` both project onto this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub cin: usize,
    pub cout: usize,
    pub h: usize,
    pub pool_after: bool,
}

/// Validate an activation DAG against its conv layers and input shape,
/// returning every tensor's `(channels, spatial side)` — index = tensor id,
/// `[0]` the network input, last entry the flatten input.
///
/// Rejects (with an error, never a panic): empty graphs, self/forward
/// tensor references (cycles), dangling tensor or conv-layer ids, conv
/// layers used twice or never, shape-mismatched adds, concats with unequal
/// spatial sides, pools on odd sides, and tensors (other than the final
/// output) that no node consumes.
pub fn check_graph(
    graph: &[GraphOp],
    layers: &[ConvShape],
    input_c: usize,
    input_hw: usize,
) -> Result<Vec<(usize, usize)>> {
    if graph.is_empty() {
        return Err(err!("graph: empty node list"));
    }
    let n_tensors = graph.len() + 1;
    let mut shapes: Vec<(usize, usize)> = Vec::with_capacity(n_tensors);
    shapes.push((input_c, input_hw));
    let mut used = vec![false; layers.len()];
    let mut read = vec![false; n_tensors];
    for (i, op) in graph.iter().enumerate() {
        for t in op.reads() {
            if t >= n_tensors {
                return Err(err!(
                    "graph node {i}: dangling tensor id {t} (graph has {n_tensors} tensors)"
                ));
            }
            if t > i {
                return Err(err!(
                    "graph node {i}: reads tensor {t} which is not yet produced \
                     (self/forward reference — the graph has a cycle)"
                ));
            }
            read[t] = true;
        }
        let out = match *op {
            GraphOp::Conv { conv, input } => {
                let l = layers.get(conv).ok_or_else(|| {
                    err!("graph node {i}: dangling conv index {conv} ({} layers)", layers.len())
                })?;
                if used[conv] {
                    return Err(err!("graph node {i}: conv layer {conv} used twice"));
                }
                used[conv] = true;
                let (c, s) = shapes[input];
                if (c, s) != (l.cin, l.h) {
                    return Err(err!(
                        "graph node {i}: conv layer {conv} expects [{}, {}, {}], \
                         input tensor {input} is [{c}, {s}, {s}]",
                        l.cin,
                        l.h,
                        l.h
                    ));
                }
                if l.pool_after {
                    if l.h % 2 != 0 {
                        return Err(err!(
                            "graph node {i}: pool after conv layer {conv} needs an even side, got {}",
                            l.h
                        ));
                    }
                    (l.cout, l.h / 2)
                } else {
                    (l.cout, l.h)
                }
            }
            GraphOp::Add { a, b } => {
                if shapes[a] != shapes[b] {
                    return Err(err!(
                        "graph node {i}: add shape mismatch — tensor {a} is {:?}, tensor {b} is {:?}",
                        shapes[a],
                        shapes[b]
                    ));
                }
                shapes[a]
            }
            GraphOp::Concat { a, b } => {
                let ((ca, sa), (cb, sb)) = (shapes[a], shapes[b]);
                if sa != sb {
                    return Err(err!(
                        "graph node {i}: concat spatial mismatch — tensor {a} side {sa}, tensor {b} side {sb}"
                    ));
                }
                (ca + cb, sa)
            }
        };
        shapes.push(out);
    }
    if let Some(unused) = used.iter().position(|&u| !u) {
        return Err(err!("graph: conv layer {unused} never used"));
    }
    // every intermediate must feed something; only the last tensor may
    // (and must) escape to the FC head
    if let Some(dead) = read.iter().take(n_tensors - 1).position(|&r| !r) {
        return Err(err!("graph: tensor {dead} is never consumed"));
    }
    Ok(shapes)
}

/// A full network variant (conv stack + FC head).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub input_c: usize,
    pub convs: Vec<ConvLayer>,
    /// FC widths after flatten; the flatten width is derived.
    pub fc: Vec<usize>,
    /// Activation DAG; `None` is the historical straight chain over
    /// `convs` ([`GraphOp::chain`]).
    pub graph: Option<Vec<GraphOp>>,
}

impl Network {
    /// VGG16 at an arbitrary square input side (224 = paper, 32 = CIFAR).
    pub fn vgg16(input_hw: usize, fft: usize, fc: Vec<usize>, name: &str) -> Self {
        let plan: [(usize, usize, usize); 5] =
            [(1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)];
        let mut convs = Vec::new();
        let mut h = input_hw;
        let mut cin = 3;
        for (blk, reps, cout) in plan {
            for i in 0..reps {
                convs.push(ConvLayer {
                    name: format!("conv{blk}_{}", i + 1),
                    cin,
                    cout,
                    h,
                    k: 3,
                    fft,
                    pool_after: i == reps - 1,
                });
                cin = cout;
            }
            h /= 2;
        }
        Network { name: name.to_string(), input_hw, input_c: 3, convs, fc, graph: None }
    }

    /// The paper's evaluation target: VGG16, 224x224, K=8.
    pub fn vgg16_224() -> Self {
        Self::vgg16(224, 8, vec![4096, 4096, 1000], "vgg16-224")
    }

    /// The K=16 variant of Table 1's lower half.
    pub fn vgg16_224_k16() -> Self {
        Self::vgg16(224, 16, vec![4096, 4096, 1000], "vgg16-224-k16")
    }

    /// CIFAR-scale VGG16 for the serving example.
    pub fn vgg16_cifar() -> Self {
        Self::vgg16(32, 8, vec![256, 10], "vgg16-cifar")
    }

    /// Tiny demo model matching the `demo` artifact variant.
    pub fn demo() -> Self {
        Network {
            name: "demo".to_string(),
            input_hw: 16,
            input_c: 1,
            convs: vec![
                ConvLayer {
                    name: "conv1".into(),
                    cin: 1,
                    cout: 8,
                    h: 16,
                    k: 3,
                    fft: 8,
                    pool_after: true,
                },
                ConvLayer {
                    name: "conv2".into(),
                    cin: 8,
                    cout: 8,
                    h: 8,
                    k: 3,
                    fft: 8,
                    pool_after: true,
                },
            ],
            fc: vec![32, 10],
            graph: None,
        }
    }

    /// Tiny residual/concat demo: the cheapest variant that exercises every
    /// [`GraphOp`] kind on the same 16x16 input as `demo`. The final conv
    /// maps the 16-channel concat back to 8 channels and pools to side 8,
    /// so the flatten width is 8·8·8 = 512.
    pub fn demo_residual() -> Self {
        let conv = |name: &str, cin: usize, cout: usize, h: usize, pool: bool| ConvLayer {
            name: name.into(),
            cin,
            cout,
            h,
            k: 3,
            fft: 8,
            pool_after: pool,
        };
        let convs = vec![
            conv("conv1", 1, 8, 16, false),
            conv("conv2", 8, 8, 16, false),
            conv("conv3", 8, 8, 16, false),
            conv("conv4", 16, 8, 16, true),
        ];
        // t0 input → t1 conv1 → t2 conv2 → t3 add(t1,t2) → t4 conv3
        //   → t5 concat(t3,t4) → t6 conv4+pool (8ch, side 8)
        let graph = vec![
            GraphOp::Conv { conv: 0, input: 0 },
            GraphOp::Conv { conv: 1, input: 1 },
            GraphOp::Add { a: 1, b: 2 },
            GraphOp::Conv { conv: 2, input: 3 },
            GraphOp::Concat { a: 3, b: 4 },
            GraphOp::Conv { conv: 3, input: 5 },
        ];
        Network {
            name: "demo-residual".to_string(),
            input_hw: 16,
            input_c: 1,
            convs,
            fc: vec![32, 10],
            graph: Some(graph),
        }
    }

    /// ResNet-18-shaped residual preset at CIFAR scale (widths /4 of the
    /// ImageNet model, 32x32 input). All downsampling happens on pooled
    /// *transition* convs between stages — the spectral layers have no
    /// stride, and a pool inside a block would break the shortcut shapes —
    /// so each stage is two basic blocks (conv, conv, add) at a fixed side:
    ///
    /// ```text
    /// conv1 3→16 @32 · [stage widths 16, 32, 64, 128; down-transition
    /// before stages 2-4 pools 32→16→8→4] · 2 blocks/stage · fc 64→10
    /// ```
    pub fn resnet18() -> Self {
        let widths = [16usize, 32, 64, 128];
        let mut convs: Vec<ConvLayer> = Vec::new();
        let mut graph: Vec<GraphOp> = Vec::new();
        let mut h = 32usize;
        let mut cin = 3usize;
        let mut cur = 0usize; // tensor id of the running activation
        let push_conv = |convs: &mut Vec<ConvLayer>,
                             graph: &mut Vec<GraphOp>,
                             cur: &mut usize,
                             name: String,
                             cin: usize,
                             cout: usize,
                             h: usize,
                             pool: bool| {
            convs.push(ConvLayer { name, cin, cout, h, k: 3, fft: 8, pool_after: pool });
            graph.push(GraphOp::Conv { conv: convs.len() - 1, input: *cur });
            *cur = graph.len();
        };
        push_conv(&mut convs, &mut graph, &mut cur, "conv1".into(), cin, widths[0], h, false);
        cin = widths[0];
        for (si, &w) in widths.iter().enumerate() {
            let stage = si + 1;
            if si > 0 {
                // pooled transition into the stage: cin→w, side halves
                push_conv(
                    &mut convs,
                    &mut graph,
                    &mut cur,
                    format!("down{stage}"),
                    cin,
                    w,
                    h,
                    true,
                );
                cin = w;
                h /= 2;
            }
            for b in 1..=2 {
                let shortcut = cur;
                push_conv(
                    &mut convs,
                    &mut graph,
                    &mut cur,
                    format!("conv{stage}_{b}a"),
                    w,
                    w,
                    h,
                    false,
                );
                push_conv(
                    &mut convs,
                    &mut graph,
                    &mut cur,
                    format!("conv{stage}_{b}b"),
                    w,
                    w,
                    h,
                    false,
                );
                graph.push(GraphOp::Add { a: shortcut, b: cur });
                cur = graph.len();
            }
        }
        Network {
            name: "resnet18".to_string(),
            input_hw: 32,
            input_c: 3,
            convs,
            fc: vec![64, 10],
            graph: Some(graph),
        }
    }

    /// The conv layers projected onto the graph checker's shape view.
    pub fn conv_shapes(&self) -> Vec<ConvShape> {
        self.convs
            .iter()
            .map(|c| ConvShape { cin: c.cin, cout: c.cout, h: c.h, pool_after: c.pool_after })
            .collect()
    }

    /// Spatial side after the full conv stack (input to flatten). Chain
    /// variants only — graph variants may end at a different channel count
    /// than the last layer's cout; use [`Network::output_shape`].
    pub fn final_side(&self) -> usize {
        let mut h = self.input_hw;
        for c in &self.convs {
            debug_assert_eq!(c.h, h, "layer {} expects side {h}", c.name);
            if c.pool_after {
                h /= 2;
            }
        }
        h
    }

    /// `(channels, spatial side)` of the tensor feeding the flatten — the
    /// graph's final output, or the last layer's for chain variants.
    pub fn output_shape(&self) -> (usize, usize) {
        match &self.graph {
            Some(g) => *check_graph(g, &self.conv_shapes(), self.input_c, self.input_hw)
                .expect("preset graphs validate")
                .last()
                .expect("non-empty graph"),
            None => {
                let c = self.convs.last().map(|c| c.cout).unwrap_or(self.input_c);
                (c, self.final_side())
            }
        }
    }

    /// Flattened width feeding the first FC layer.
    pub fn flatten_width(&self) -> usize {
        let (c, s) = self.output_shape();
        c * s * s
    }

    pub fn total_spectral_macs(&self) -> u64 {
        self.convs.iter().map(|c| c.spectral_macs()).sum()
    }

    /// Latency budget split (paper §6.1): τ_i = τ · CMP_i / CMP_total.
    pub fn latency_split(&self, total_secs: f64) -> Vec<f64> {
        let total = self.total_spectral_macs() as f64;
        self.convs
            .iter()
            .map(|c| total_secs * c.spectral_macs() as f64 / total)
            .collect()
    }

    /// Layers the paper optimizes (conv1_1 is omitted: "negligible
    /// computations", §6.1).
    pub fn optimized_convs(&self) -> Vec<&ConvLayer> {
        self.convs.iter().filter(|c| c.name != "conv1_1").collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_224_structure() {
        let n = Network::vgg16_224();
        assert_eq!(n.convs.len(), 13);
        assert_eq!(n.convs[0].name, "conv1_1");
        assert_eq!(n.convs[0].cin, 3);
        assert_eq!(n.convs[12].name, "conv5_3");
        assert_eq!(n.convs[12].cout, 512);
        assert_eq!(n.convs.iter().filter(|c| c.pool_after).count(), 5);
        assert_eq!(n.final_side(), 7);
        assert_eq!(n.flatten_width(), 512 * 7 * 7);
    }

    #[test]
    fn tile_counts_match_paper_geometry() {
        let n = Network::vgg16_224();
        let tiles: Vec<usize> = n.convs.iter().map(|c| c.num_tiles()).collect();
        assert_eq!(
            tiles,
            [1444, 1444, 361, 361, 100, 100, 100, 25, 25, 25, 9, 9, 9]
        );
    }

    #[test]
    fn spectral_beats_spatial_in_most_layers() {
        // The paper's headline: ~2-3x complexity reduction at K=8. The ratio
        // holds for every layer past conv1 (small channel counts don't
        // amortize tile padding).
        let n = Network::vgg16_224();
        for c in &n.convs[2..] {
            let ratio = c.spatial_macs() as f64 / c.spectral_macs() as f64;
            assert!(ratio > 1.5, "{}: ratio {ratio}", c.name);
        }
    }

    #[test]
    fn kernel_explosion_factor() {
        // 3x3 real -> 8x8 complex: 64*2/9 ≈ 14.2x storage (paper: ~15x).
        let c = &Network::vgg16_224().convs[1];
        let spatial = (c.cout * c.cin * c.k * c.k) as f64;
        let spectral = c.spectral_kernel_elems() as f64 * 2.0; // complex
        let factor = spectral / spatial;
        assert!(factor > 14.0 && factor < 15.0, "factor {factor}");
    }

    #[test]
    fn latency_split_sums_to_total() {
        let n = Network::vgg16_224();
        let split = n.latency_split(0.020);
        assert_eq!(split.len(), 13);
        let sum: f64 = split.iter().sum();
        assert!((sum - 0.020).abs() < 1e-9);
        assert!(split.iter().all(|&t| t > 0.0));
        // conv3_2 (100 tiles × 256×256 channels) carries the most spectral
        // MACs; conv1_1 the fewest by far.
        let max = split.iter().cloned().fold(0.0, f64::max);
        assert!((split[5] - max).abs() < 1e-12, "expected conv3_2 max: {split:?}");
        let min = split.iter().cloned().fold(f64::MAX, f64::min);
        assert!((split[0] - min).abs() < 1e-12);
    }

    #[test]
    fn optimized_set_drops_conv1_1() {
        let n = Network::vgg16_224();
        let opt = n.optimized_convs();
        assert_eq!(opt.len(), 12);
        assert!(opt.iter().all(|c| c.name != "conv1_1"));
    }

    #[test]
    fn cifar_and_demo_consistent() {
        let c = Network::vgg16_cifar();
        assert_eq!(c.final_side(), 1);
        assert_eq!(c.flatten_width(), 512);
        let d = Network::demo();
        assert_eq!(d.final_side(), 4);
        assert_eq!(d.flatten_width(), 8 * 4 * 4);
    }

    #[test]
    fn k16_variant_tiles() {
        let n = Network::vgg16_224_k16();
        // K=16, k=3 → h'=14; 224/14 = 16 → 256 tiles in conv1.
        assert_eq!(n.convs[0].num_tiles(), 256);
    }

    #[test]
    fn resnet18_structure() {
        let n = Network::resnet18();
        // conv1 + stage1 (4) + three down-transitions + 4 convs each
        assert_eq!(n.convs.len(), 20);
        let g = n.graph.as_ref().unwrap();
        assert_eq!(g.len(), 28); // 20 convs + 8 residual adds
        let adds = g.iter().filter(|op| matches!(op, GraphOp::Add { .. })).count();
        assert_eq!(adds, 8);
        assert!(!g.iter().any(|op| matches!(op, GraphOp::Concat { .. })));
        let shapes = check_graph(g, &n.conv_shapes(), n.input_c, n.input_hw).unwrap();
        assert_eq!(*shapes.last().unwrap(), (128, 4));
        assert_eq!(n.output_shape(), (128, 4));
        assert_eq!(n.flatten_width(), 2048);
        // every add joins two same-shape tensors — already enforced by
        // check_graph, but pin the shortcut spans: each add's `a` is
        // produced 2 nodes before its `b`.
        for op in g {
            if let GraphOp::Add { a, b } = op {
                assert_eq!(b - a, 2, "basic block spans two convs");
            }
        }
    }

    #[test]
    fn demo_residual_structure() {
        let n = Network::demo_residual();
        assert_eq!(n.convs.len(), 4);
        let g = n.graph.as_ref().unwrap();
        assert!(g.iter().any(|op| matches!(op, GraphOp::Add { .. })));
        assert!(g.iter().any(|op| matches!(op, GraphOp::Concat { .. })));
        assert_eq!(n.output_shape(), (8, 8));
        assert_eq!(n.flatten_width(), 512);
    }

    #[test]
    fn chain_matches_implicit_graph() {
        // A chain-graph demo must agree with the graph-less demo everywhere.
        let d = Network::demo();
        let mut chained = d.clone();
        chained.graph = Some(GraphOp::chain(d.convs.len()));
        assert_eq!(chained.output_shape(), d.output_shape());
        assert_eq!(chained.flatten_width(), d.flatten_width());
    }

    #[test]
    fn check_graph_rejects_malformed() {
        let layers = vec![
            ConvShape { cin: 1, cout: 8, h: 16, pool_after: false },
            ConvShape { cin: 8, cout: 8, h: 16, pool_after: false },
        ];
        let ok = vec![GraphOp::Conv { conv: 0, input: 0 }, GraphOp::Conv { conv: 1, input: 1 }];
        assert!(check_graph(&ok, &layers, 1, 16).is_ok());

        // empty
        assert!(check_graph(&[], &layers, 1, 16).is_err());
        // self/forward reference (cycle)
        let cyc = vec![GraphOp::Conv { conv: 0, input: 1 }, GraphOp::Conv { conv: 1, input: 2 }];
        let e = check_graph(&cyc, &layers, 1, 16).unwrap_err();
        assert!(format!("{e}").contains("cycle"), "{e}");
        // dangling tensor id
        let dangle = vec![GraphOp::Conv { conv: 0, input: 0 }, GraphOp::Conv { conv: 1, input: 9 }];
        assert!(check_graph(&dangle, &layers, 1, 16).is_err());
        // dangling conv index
        let badconv = vec![GraphOp::Conv { conv: 7, input: 0 }];
        assert!(check_graph(&badconv, &layers, 1, 16).is_err());
        // conv used twice / never
        let twice = vec![GraphOp::Conv { conv: 0, input: 0 }, GraphOp::Conv { conv: 0, input: 1 }];
        assert!(check_graph(&twice, &layers, 1, 16).is_err());
        // add shape mismatch (t0 is 1ch, t1 is 8ch)
        let badadd = vec![
            GraphOp::Conv { conv: 0, input: 0 },
            GraphOp::Conv { conv: 1, input: 1 },
            GraphOp::Add { a: 0, b: 2 },
        ];
        let e = check_graph(&badadd, &layers, 1, 16).unwrap_err();
        assert!(format!("{e}").contains("mismatch"), "{e}");
        // dead intermediate: t1 feeds nothing once t0 goes to both convs
        let layers2 = vec![
            ConvShape { cin: 1, cout: 8, h: 16, pool_after: false },
            ConvShape { cin: 1, cout: 8, h: 16, pool_after: false },
        ];
        let dead = vec![GraphOp::Conv { conv: 0, input: 0 }, GraphOp::Conv { conv: 1, input: 0 }];
        let e = check_graph(&dead, &layers2, 1, 16).unwrap_err();
        assert!(format!("{e}").contains("never consumed"), "{e}");
    }

    #[test]
    fn check_graph_rejects_concat_and_pool_errors() {
        // concat spatial mismatch: pooled branch vs unpooled input
        let layers = vec![ConvShape { cin: 1, cout: 8, h: 16, pool_after: true }];
        let bad = vec![GraphOp::Conv { conv: 0, input: 0 }, GraphOp::Concat { a: 0, b: 1 }];
        let e = check_graph(&bad, &layers, 1, 16).unwrap_err();
        assert!(format!("{e}").contains("concat spatial mismatch"), "{e}");
        // pool on an odd side
        let odd = vec![ConvShape { cin: 1, cout: 8, h: 15, pool_after: true }];
        let g = vec![GraphOp::Conv { conv: 0, input: 0 }];
        assert!(check_graph(&g, &odd, 1, 15).is_err());
    }
}
