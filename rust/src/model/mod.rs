//! Model descriptions: spectral conv layer specs and the VGG16 presets the
//! paper evaluates (§6). Mirrors `python/compile/model.py`; the runtime
//! cross-checks this table against `artifacts/manifest.json`.

use crate::fft::TileGeometry;

/// One spectral convolutional layer (paper notation in parens).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: String,
    /// Input channels (M).
    pub cin: usize,
    /// Output channels (N).
    pub cout: usize,
    /// Input spatial side (h_in = w_in).
    pub h: usize,
    /// Spatial kernel side (k).
    pub k: usize,
    /// FFT window (K).
    pub fft: usize,
    /// 2x2 maxpool follows this layer.
    pub pool_after: bool,
}

impl ConvLayer {
    pub fn geometry(&self) -> TileGeometry {
        TileGeometry::new(self.h, self.fft, self.k)
    }

    /// Total tile count P for one image (paper: h_in*w_in / h'w').
    pub fn num_tiles(&self) -> usize {
        self.geometry().num_tiles()
    }

    /// Spectral multiply-accumulate count for one image: every (tile,
    /// cout, cin) needs K² complex MACs (paper §6.1 uses this to split the
    /// latency budget: τ_i = τ · CMP_i / CMP_total).
    pub fn spectral_macs(&self) -> u64 {
        (self.num_tiles() as u64)
            * (self.cin as u64)
            * (self.cout as u64)
            * (self.fft * self.fft) as u64
    }

    /// Spatial-domain MACs (for the complexity-reduction comparison).
    pub fn spatial_macs(&self) -> u64 {
        (self.h as u64)
            * (self.h as u64)
            * (self.cin as u64)
            * (self.cout as u64)
            * (self.k * self.k) as u64
    }

    /// Dense spectral kernel element count (the "kernel explosion").
    pub fn spectral_kernel_elems(&self) -> u64 {
        (self.cout * self.cin * self.fft * self.fft) as u64
    }
}

/// A full network variant (conv stack + FC head).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub input_c: usize,
    pub convs: Vec<ConvLayer>,
    /// FC widths after flatten; the flatten width is derived.
    pub fc: Vec<usize>,
}

impl Network {
    /// VGG16 at an arbitrary square input side (224 = paper, 32 = CIFAR).
    pub fn vgg16(input_hw: usize, fft: usize, fc: Vec<usize>, name: &str) -> Self {
        let plan: [(usize, usize, usize); 5] =
            [(1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)];
        let mut convs = Vec::new();
        let mut h = input_hw;
        let mut cin = 3;
        for (blk, reps, cout) in plan {
            for i in 0..reps {
                convs.push(ConvLayer {
                    name: format!("conv{blk}_{}", i + 1),
                    cin,
                    cout,
                    h,
                    k: 3,
                    fft,
                    pool_after: i == reps - 1,
                });
                cin = cout;
            }
            h /= 2;
        }
        Network { name: name.to_string(), input_hw, input_c: 3, convs, fc }
    }

    /// The paper's evaluation target: VGG16, 224x224, K=8.
    pub fn vgg16_224() -> Self {
        Self::vgg16(224, 8, vec![4096, 4096, 1000], "vgg16-224")
    }

    /// The K=16 variant of Table 1's lower half.
    pub fn vgg16_224_k16() -> Self {
        Self::vgg16(224, 16, vec![4096, 4096, 1000], "vgg16-224-k16")
    }

    /// CIFAR-scale VGG16 for the serving example.
    pub fn vgg16_cifar() -> Self {
        Self::vgg16(32, 8, vec![256, 10], "vgg16-cifar")
    }

    /// Tiny demo model matching the `demo` artifact variant.
    pub fn demo() -> Self {
        Network {
            name: "demo".to_string(),
            input_hw: 16,
            input_c: 1,
            convs: vec![
                ConvLayer {
                    name: "conv1".into(),
                    cin: 1,
                    cout: 8,
                    h: 16,
                    k: 3,
                    fft: 8,
                    pool_after: true,
                },
                ConvLayer {
                    name: "conv2".into(),
                    cin: 8,
                    cout: 8,
                    h: 8,
                    k: 3,
                    fft: 8,
                    pool_after: true,
                },
            ],
            fc: vec![32, 10],
        }
    }

    /// Spatial side after the full conv stack (input to flatten).
    pub fn final_side(&self) -> usize {
        let mut h = self.input_hw;
        for c in &self.convs {
            debug_assert_eq!(c.h, h, "layer {} expects side {h}", c.name);
            if c.pool_after {
                h /= 2;
            }
        }
        h
    }

    /// Flattened width feeding the first FC layer.
    pub fn flatten_width(&self) -> usize {
        let s = self.final_side();
        self.convs.last().map(|c| c.cout).unwrap_or(self.input_c) * s * s
    }

    pub fn total_spectral_macs(&self) -> u64 {
        self.convs.iter().map(|c| c.spectral_macs()).sum()
    }

    /// Latency budget split (paper §6.1): τ_i = τ · CMP_i / CMP_total.
    pub fn latency_split(&self, total_secs: f64) -> Vec<f64> {
        let total = self.total_spectral_macs() as f64;
        self.convs
            .iter()
            .map(|c| total_secs * c.spectral_macs() as f64 / total)
            .collect()
    }

    /// Layers the paper optimizes (conv1_1 is omitted: "negligible
    /// computations", §6.1).
    pub fn optimized_convs(&self) -> Vec<&ConvLayer> {
        self.convs.iter().filter(|c| c.name != "conv1_1").collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_224_structure() {
        let n = Network::vgg16_224();
        assert_eq!(n.convs.len(), 13);
        assert_eq!(n.convs[0].name, "conv1_1");
        assert_eq!(n.convs[0].cin, 3);
        assert_eq!(n.convs[12].name, "conv5_3");
        assert_eq!(n.convs[12].cout, 512);
        assert_eq!(n.convs.iter().filter(|c| c.pool_after).count(), 5);
        assert_eq!(n.final_side(), 7);
        assert_eq!(n.flatten_width(), 512 * 7 * 7);
    }

    #[test]
    fn tile_counts_match_paper_geometry() {
        let n = Network::vgg16_224();
        let tiles: Vec<usize> = n.convs.iter().map(|c| c.num_tiles()).collect();
        assert_eq!(
            tiles,
            [1444, 1444, 361, 361, 100, 100, 100, 25, 25, 25, 9, 9, 9]
        );
    }

    #[test]
    fn spectral_beats_spatial_in_most_layers() {
        // The paper's headline: ~2-3x complexity reduction at K=8. The ratio
        // holds for every layer past conv1 (small channel counts don't
        // amortize tile padding).
        let n = Network::vgg16_224();
        for c in &n.convs[2..] {
            let ratio = c.spatial_macs() as f64 / c.spectral_macs() as f64;
            assert!(ratio > 1.5, "{}: ratio {ratio}", c.name);
        }
    }

    #[test]
    fn kernel_explosion_factor() {
        // 3x3 real -> 8x8 complex: 64*2/9 ≈ 14.2x storage (paper: ~15x).
        let c = &Network::vgg16_224().convs[1];
        let spatial = (c.cout * c.cin * c.k * c.k) as f64;
        let spectral = c.spectral_kernel_elems() as f64 * 2.0; // complex
        let factor = spectral / spatial;
        assert!(factor > 14.0 && factor < 15.0, "factor {factor}");
    }

    #[test]
    fn latency_split_sums_to_total() {
        let n = Network::vgg16_224();
        let split = n.latency_split(0.020);
        assert_eq!(split.len(), 13);
        let sum: f64 = split.iter().sum();
        assert!((sum - 0.020).abs() < 1e-9);
        assert!(split.iter().all(|&t| t > 0.0));
        // conv3_2 (100 tiles × 256×256 channels) carries the most spectral
        // MACs; conv1_1 the fewest by far.
        let max = split.iter().cloned().fold(0.0, f64::max);
        assert!((split[5] - max).abs() < 1e-12, "expected conv3_2 max: {split:?}");
        let min = split.iter().cloned().fold(f64::MAX, f64::min);
        assert!((split[0] - min).abs() < 1e-12);
    }

    #[test]
    fn optimized_set_drops_conv1_1() {
        let n = Network::vgg16_224();
        let opt = n.optimized_convs();
        assert_eq!(opt.len(), 12);
        assert!(opt.iter().all(|c| c.name != "conv1_1"));
    }

    #[test]
    fn cifar_and_demo_consistent() {
        let c = Network::vgg16_cifar();
        assert_eq!(c.final_side(), 1);
        assert_eq!(c.flatten_width(), 512);
        let d = Network::demo();
        assert_eq!(d.final_side(), 4);
        assert_eq!(d.flatten_width(), 8 * 4 * 4);
    }

    #[test]
    fn k16_variant_tiles() {
        let n = Network::vgg16_224_k16();
        // K=16, k=3 → h'=14; 224/14 = 16 → 256 tiles in conv1.
        assert_eq!(n.convs[0].num_tiles(), 256);
    }
}
