//! Scheduler demo: regenerates the PE-utilization artifacts — Fig. 8
//! (per-layer, 3 schedulers, r=8), Fig. 9 (avg utilization vs replicas,
//! ADMM-like kernels) and Fig. 10 (random sparsity) — and shows one
//! compiled INDEX/VALUE table (Fig. 6) executing conflict-free on the
//! BRAM-replica model.
//!
//! ```bash
//! cargo run --release --example scheduler_demo [-- --samples 16]
//! ```

use spectral_flow::model::Network;
use spectral_flow::report::{fmt_pct, Table};
use spectral_flow::schedule::tables::compile_tables;
use spectral_flow::schedule::{sampled_layer_utilization, schedule_exact_cover, Scheduler};
use spectral_flow::sim::execute_tables;
use spectral_flow::sparse::{prune_magnitude, prune_random, SparseLayer};
use spectral_flow::util::cli::Args;
use spectral_flow::util::error::Result;
use spectral_flow::util::rng::Pcg32;

const N_PAR: usize = 64;

/// Sampling seed: historical value, keeps regenerated figures comparable.
const SAMPLE_SEED: u64 = 77;

/// MAC-weighted average PE utilization of one scheduler over a layer.
fn layer_utilization(sparse: &SparseLayer, sch: Scheduler, r: usize, samples: usize) -> f64 {
    sampled_layer_utilization(sparse, sch, N_PAR, r, samples, SAMPLE_SEED)
}

/// Sparse layers for one (α, pattern) setting, generated once per sweep.
fn gen_layers(net: &Network, alpha: usize, random: bool) -> Vec<(SparseLayer, f64)> {
    let mut rng = Pcg32::new(2020 + alpha as u64);
    net.optimized_convs()
        .iter()
        .map(|conv| {
            let sparse = if random {
                prune_random(conv.cout, conv.cin, conv.fft, alpha, &mut rng)
            } else {
                prune_magnitude(conv.cout, conv.cin, conv.fft, alpha, &mut rng)
            };
            (sparse, conv.spectral_macs() as f64)
        })
        .collect()
}

/// FLOP-weighted network average (paper Fig. 9 weighting).
fn avg_utilization(layers: &[(SparseLayer, f64)], sch: Scheduler, r: usize, samples: usize) -> f64 {
    let (mut num, mut den) = (0.0, 0.0);
    for (sparse, w) in layers {
        num += layer_utilization(sparse, sch, r, samples) * w;
        den += w;
    }
    num / den
}

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let samples = args.opt_usize("samples", 12, "scheduling instances sampled per layer");
    args.maybe_help("scheduler_demo: Figs 8/9/10 + a Fig 6 table execution");
    let net = Network::vgg16_224();

    // ---- Fig 8: per layer, r=8, α=4, ADMM-like ---------------------------
    let mut fig8 = Table::new(
        "Fig 8 — PE utilization per layer (r=8, N'=64, α=4, ADMM-like)",
        &["layer", "exact-cover", "lowest-index", "random"],
    );
    let mut rng = Pcg32::new(2020);
    for conv in net.optimized_convs() {
        let sparse = prune_magnitude(conv.cout, conv.cin, conv.fft, 4, &mut rng);
        fig8.row(vec![
            conv.name.clone(),
            fmt_pct(layer_utilization(&sparse, Scheduler::ExactCover, 8, samples)),
            fmt_pct(layer_utilization(&sparse, Scheduler::LowestIndexFirst, 8, samples)),
            fmt_pct(layer_utilization(&sparse, Scheduler::Random, 8, samples)),
        ]);
    }
    println!("{}", fig8.render());
    let _ = fig8.save_csv("fig8");

    // ---- Figs 9/10: average utilization vs replicas ----------------------
    for (fig, random) in [("Fig 9 (ADMM-like)", false), ("Fig 10 (random non-zeros)", true)] {
        let mut t = Table::new(
            &format!("{fig} — avg PE utilization vs replicas r (N'=64)"),
            &["r", "EC α=4", "LI α=4", "RD α=4", "EC α=8", "LI α=8", "RD α=8"],
        );
        let layers4 = gen_layers(&net, 4, random);
        let layers8 = gen_layers(&net, 8, random);
        for r in [4usize, 6, 8, 10, 12, 16, 20] {
            let mut cells = vec![r.to_string()];
            for layers in [&layers4, &layers8] {
                for sch in Scheduler::ALL {
                    cells.push(fmt_pct(avg_utilization(layers, sch, r, samples)));
                }
            }
            t.row(cells);
        }
        println!("{}", t.render());
        let _ = t.save_csv(if random { "fig10" } else { "fig9" });
    }

    // ---- Fig 6: table compilation + conflict-free execution --------------
    let mut rng = Pcg32::new(5);
    let layer = prune_magnitude(N_PAR, 4, 8, 4, &mut rng);
    let kernels = layer.group_indices(0, N_PAR, 0);
    let sched = schedule_exact_cover(&kernels, 10);
    sched.validate(&kernels).expect("legal schedule");
    let tables = compile_tables(&sched, &layer, 0, 0, N_PAR);
    let tiles: Vec<Vec<(f32, f32)>> = (0..9)
        .map(|t| (0..64).map(|i| ((t * 64 + i) as f32 * 0.01, 0.5)).collect())
        .collect();
    let exec = execute_tables(&tables, &tiles, 10, 64);
    println!(
        "Fig 6 check — 64 kernels × 9 tiles, r=10: {} cycles, {} MACs, {} conflicts, PE util {}",
        exec.cycles,
        exec.macs,
        exec.conflicts,
        fmt_pct(sched.pe_utilization()),
    );
    assert_eq!(exec.conflicts, 0);
    println!("\nscheduler_demo OK");
    Ok(())
}
