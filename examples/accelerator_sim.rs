//! Accelerator simulation: regenerates Table 3 (device comparison) and the
//! Fig. 11 resource report on the cycle-level U200 model.
//!
//! ```bash
//! cargo run --release --example accelerator_sim [-- --samples 24 --full]
//! ```

use spectral_flow::analysis::ArchParams;
use spectral_flow::dataflow::{optimize_network_at, OptimizerConfig};
use spectral_flow::model::Network;
use spectral_flow::report::{fmt_gbps, fmt_ms, fmt_pct, Table};
use spectral_flow::sim::baselines::{run_baseline, sparse_spatial_17_latency, BaselineConfig};
use spectral_flow::sim::{estimate_resources, SimConfig};
use spectral_flow::util::cli::Args;
use spectral_flow::util::error::Result;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let samples = args.opt_usize("samples", 24, "scheduling instances per layer");
    let full = args.opt_bool("full", "schedule every instance (slow, exact)");
    args.maybe_help("accelerator_sim: Table 3 + Fig 11 on the U200 model");
    let sample_groups = if full { None } else { Some(samples) };

    let net = Network::vgg16_224();
    let mut t3 = Table::new(
        "Table 3 — VGG16-224 conv stack on the simulated U200",
        &["design", "latency", "fps", "BW req", "avg PE util", "DDR traffic MB"],
    );
    for cfg in BaselineConfig::all() {
        let t0 = std::time::Instant::now();
        let res = run_baseline(&cfg, &net, sample_groups, 2020);
        t3.row(vec![
            cfg.name.to_string(),
            fmt_ms(res.latency_secs()),
            format!("{:.0}", res.throughput_fps()),
            fmt_gbps(res.required_bandwidth()),
            fmt_pct(res.avg_pe_utilization()),
            format!("{:.0}", res.total_ddr_bytes() as f64 / 1e6),
        ]);
        eprintln!("  simulated {:<28} in {:?}", cfg.name, t0.elapsed());
    }
    t3.row(vec![
        "[17]-like (sparse spatial)".into(),
        fmt_ms(sparse_spatial_17_latency(&net, 4)),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("{}", t3.render());
    let _ = t3.save_csv("table3");

    // Paper reference points for eyeballing (from Table 3 of the paper):
    println!("paper reference: this-work 9 ms / 112 fps / 12 GB/s; [16] 68 ms @ 9 GB/s;");
    println!("                 [27] 250 ms; [26] 167 ms; [17] 200 ms (Artix, 100 MHz)\n");

    // ---- Fig 11: resource utilization ------------------------------------
    let ocfg = OptimizerConfig::paper();
    let plan = optimize_network_at(&net, ArchParams::paper(), &ocfg).expect("feasible");
    let plans: Vec<_> = plan.layers.iter().map(|l| (l.params, l.stream)).collect();
    let res = estimate_resources(
        &ArchParams::paper(),
        &plans,
        SimConfig::default().fft_butterflies_per_cycle,
    );
    println!("Fig 11 — resource estimate @ P'=9, N'=64: {}", res.utilization_report());
    println!("paper reference: DSP 2680/6840, BRAM 1469/2160, LUT 230K/1.2M, 200 MHz");
    Ok(())
}
