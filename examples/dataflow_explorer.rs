//! Dataflow explorer: regenerates the analysis-side artifacts —
//! Fig. 2 (fixed-flow complexity), Fig. 7 (fixed vs flexible), Table 1
//! (optimal streaming parameters) and Table 2 (per-layer bandwidth) —
//! for VGG16 at K=8 and K=16.
//!
//! ```bash
//! cargo run --release --example dataflow_explorer [-- --alpha 4]
//! ```

use spectral_flow::analysis::{
    bram_flow, transfers_flow, ArchParams, Flow, LayerParams,
};
use spectral_flow::dataflow::{optimize_network, optimize_network_at, OptimizerConfig};
use spectral_flow::model::Network;
use spectral_flow::report::{fmt_bytes, fmt_gbps, fmt_ms, Table};
use spectral_flow::util::cli::Args;
use spectral_flow::util::error::Result;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let alpha = args.opt_usize("alpha", 4, "compression ratio α");
    let tau_ms = args.opt_f64("tau-ms", 20.0, "conv-stack latency budget (paper §6.1)");
    args.maybe_help("dataflow_explorer: Figs 2/7 + Tables 1/2");

    let cfg = OptimizerConfig { alpha, total_latency: tau_ms / 1e3, ..OptimizerConfig::paper() };

    for (net, arch) in [
        (Network::vgg16_224(), ArchParams::paper()),
        (Network::vgg16_224_k16(), ArchParams { p_par: 16, n_par: 32, replicas: 10 }),
    ] {
        println!("\n################ {} (P'={}, N'={}) ################\n", net.name, arch.p_par, arch.n_par);

        // ---- Fig 2: fixed flows --------------------------------------
        let mut fig2 = Table::new(
            &format!("Fig 2 — α={alpha}: transfers (MB @2B words) and BRAMs per fixed flow"),
            &["layer", "xfer F1", "xfer F2", "xfer F3", "bram F1", "bram F2", "bram F3"],
        );
        for conv in net.optimized_convs() {
            let l = LayerParams::from_layer(conv, alpha);
            let mut cells = vec![conv.name.clone()];
            for f in Flow::ALL {
                cells.push(format!("{:.1}", transfers_flow(f, &l, &arch).total() as f64 * 2.0 / 1e6));
            }
            for f in Flow::ALL {
                cells.push(bram_flow(f, &l, &arch).to_string());
            }
            fig2.row(cells);
        }
        println!("{}", fig2.render());

        // ---- Table 1 + Fig 7 + Table 2: the flexible flow ------------
        let Some(plan) = optimize_network_at(&net, arch, &cfg) else {
            println!("(no feasible flexible plan at this arch point)");
            continue;
        };
        let mut t1 = Table::new(
            &format!("Table 1 — optimal streaming parameters ({})", net.name),
            &["layer", "Ps", "Ns"],
        );
        let mut fig7 = Table::new(
            "Fig 7 — transfers: Flow #1 vs Flow #2 vs Flow opt (MB)",
            &["layer", "Flow#1", "Flow#2", "Flow opt", "opt BRAMs"],
        );
        let mut t2 = Table::new(
            &format!("Table 2 — required bandwidth under Flow opt (τ={tau_ms} ms)"),
            &["layer", "τ_i", "BW"],
        );
        for lp in &plan.layers {
            t1.row(vec![lp.layer_name.clone(), lp.stream.ps.to_string(), lp.stream.ns.to_string()]);
            let f1 = transfers_flow(Flow::ReuseKernels, &lp.params, &arch).total();
            let f2 = transfers_flow(Flow::ReuseInputs, &lp.params, &arch).total();
            fig7.row(vec![
                lp.layer_name.clone(),
                format!("{:.1}", f1 as f64 * 2.0 / 1e6),
                format!("{:.1}", f2 as f64 * 2.0 / 1e6),
                format!("{:.1}", lp.transfers.total() as f64 * 2.0 / 1e6),
                lp.brams.to_string(),
            ]);
            t2.row(vec![lp.layer_name.clone(), fmt_ms(lp.tau), fmt_gbps(lp.bandwidth)]);
        }
        println!("{}", t1.render());
        println!("{}", fig7.render());
        println!("{}", t2.render());
        println!(
            "total transfers: {}   max bandwidth: {}",
            fmt_bytes(plan.total_transfers() * 2),
            fmt_gbps(plan.bw_max)
        );
        let _ = fig7.save_csv(&format!("fig7_{}", net.name));
        let _ = t2.save_csv(&format!("table2_{}", net.name));
    }

    // Joint architecture search (Alg 1 outer loop).
    let net = Network::vgg16_224();
    if let Some(best) = optimize_network(&net, &cfg) {
        println!(
            "\nAlg 1 architecture search optimum: P'={}, N'={} (bw_max {})",
            best.arch.p_par,
            best.arch.n_par,
            fmt_gbps(best.bw_max)
        );
    }
    Ok(())
}
