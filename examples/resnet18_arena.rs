//! ResNet-18 residual graphs through the activation arena.
//!
//! The paper's dataflow assumes a straight-line VGG forward — one live
//! activation between layers. This example runs the `resnet18` preset
//! (CIFAR-scale widths, 8 shortcut adds) end to end and shows what the
//! lifetime-based arena buys: shortcut tensors stay resident in their own
//! slot across the block (never copied), everything else ping-pongs
//! through reused slots, and peak activation memory lands far below the
//! one-buffer-per-tensor sum.
//!
//! Runs fully offline on the default `interp` backend:
//!
//! ```bash
//! cargo run --release --example resnet18_arena
//! ```

use spectral_flow::coordinator::{EngineOptions, InferenceEngine, WeightMode};
use spectral_flow::util::error::Result;

fn main() -> Result<()> {
    println!("spectral-flow resnet18 arena");
    println!("============================\n");

    // 1. Build the residual engine. The arena plan is computed once here —
    //    last-use analysis over the graph, then a linear scan into slots.
    let t0 = std::time::Instant::now();
    let mut engine =
        InferenceEngine::new("artifacts", "resnet18", WeightMode::Pruned { alpha: 4 }, 42)?;
    let plan = engine.arena().clone();
    println!(
        "engine up ({} convs, {} graph nodes, backend {}) in {:?}",
        engine.variant.layers.len(),
        plan.steps.len(),
        engine.backend_name(),
        t0.elapsed()
    );

    // 2. The arena plan: 29 tensors share 3 slots — one for the current
    //    input, one for the current output, one pinning the live shortcut.
    let am = engine.arena_metrics().clone();
    println!("{}", am.report());
    assert!(am.peak_activation_bytes < am.no_reuse_bytes, "reuse must beat flat allocation");
    println!(
        "slot reuse cuts peak activation memory {:.1}x vs one-buffer-per-tensor ✓",
        am.no_reuse_bytes as f64 / am.peak_activation_bytes as f64
    );

    // 3. Forward a single image and a batch through the graph executor.
    let img = engine.synthetic_image(1);
    let t1 = std::time::Instant::now();
    let logits = engine.forward(&img)?;
    println!("\nforward(resnet18 32x32) in {:?} → {} logits", t1.elapsed(), logits.len());
    let batch: Vec<_> = (1u64..=4).map(|s| engine.synthetic_image(s)).collect();
    let out = engine.forward_batch(&batch)?;
    assert_eq!(out[0], logits, "batch lane 0 must match the single forward");
    println!("forward_batch(B=4) lane 0 == single forward, bit-for-bit ✓");

    // 4. Safety check the property tests pin: slot reuse must be purely an
    //    allocation concern. Disable it (every tensor gets its own slot)
    //    and the logits must not move by a single bit.
    let mut flat = InferenceEngine::with_options(
        "artifacts",
        "resnet18",
        WeightMode::Pruned { alpha: 4 },
        42,
        EngineOptions { arena_reuse: false, ..EngineOptions::default() },
    )?;
    let logits_flat = flat.forward(&img)?;
    assert_eq!(logits, logits_flat, "arena reuse changed the numbers");
    println!(
        "arena reuse ({} slots) == no-reuse ({} slots), bit-for-bit ✓",
        am.slots,
        flat.arena_metrics().slots
    );

    println!("\nresnet18 arena OK");
    Ok(())
}
