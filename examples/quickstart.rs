//! Quickstart: build the `demo` engine, run one spectral conv layer through
//! the backend, and validate it against the pure-Rust spatial convolution
//! reference — the smallest end-to-end proof that the spectral pipeline
//! (tile → FFT → Hadamard → IFFT → overlap-add) composes.
//!
//! Runs fully offline on the default `interp` backend — no artifacts, no
//! network, no external crates:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use spectral_flow::coordinator::{InferenceEngine, WeightMode};
use spectral_flow::runtime::BackendKind;
use spectral_flow::util::check::assert_allclose;
use spectral_flow::util::error::Result;

fn main() -> Result<()> {
    println!("spectral-flow quickstart");
    println!("========================\n");

    // Dense weights so the spatial reference is exact.
    let t0 = std::time::Instant::now();
    let mut engine = InferenceEngine::new("artifacts", "demo", WeightMode::Dense, 42)?;
    println!(
        "engine up ({} layers, backend {}) in {:?}",
        engine.variant.layers.len(),
        engine.backend_name(),
        t0.elapsed()
    );

    // 1. One conv layer: backend spectral path vs Rust spatial reference.
    let img = engine.synthetic_image(1);
    let spectral = engine.conv_layer(0, &img)?;
    let spatial = engine.conv_layer_reference(0, &img)?;
    assert_allclose(spectral.data(), spatial.data(), 1e-3, 1e-3);
    println!(
        "conv1 spectral == spatial reference ✓  (max |err| = {:.2e})",
        spectral.max_abs_diff(&spatial)
    );

    // 2. Tile-parallel backend: same layer on 2 interp threads must be
    //    bit-for-bit identical to the serial path (tiles are independent).
    let mut par = InferenceEngine::new_with(
        "artifacts",
        "demo",
        WeightMode::Dense,
        42,
        BackendKind::Interp { threads: 2 },
    )?;
    let spectral2 = par.conv_layer(0, &img)?;
    assert_eq!(spectral.data(), spectral2.data(), "threaded interp diverged");
    println!("conv1 on 2 backend threads == serial, bit-for-bit ✓");

    // 3. Full forward pass (conv → pool → conv → pool → FC → logits).
    let t1 = std::time::Instant::now();
    let logits = engine.forward(&img)?;
    println!(
        "forward(demo 16x16) in {:?} → logits {:?}",
        t1.elapsed(),
        logits.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    // 4. Same pass with pruned (α=4) spectral kernels — the paper's
    //    regime: kernels upload in CSR form and the backend's sparse MAC
    //    touches only the K²/α stored non-zeros (see docs/ARCHITECTURE.md).
    let mut pruned =
        InferenceEngine::new("artifacts", "demo", WeightMode::Pruned { alpha: 4 }, 42)?;
    let logits_p = pruned.forward(&img)?;
    println!("forward with α=4 pruned kernels (sparse CSR MAC) → {} logits ✓", logits_p.len());

    println!("\nquickstart OK");
    Ok(())
}
