//! End-to-end driver (DESIGN.md E10): serve batched VGG16 inference through
//! the full stack — Rust batching server → spectral backend (pure-Rust
//! `interp` by default; PJRT executables behind the `pjrt` feature) →
//! Rust OaA/pool/FC — and report latency/throughput. Also measures the
//! single-image 224×224 forward pass, the workload Table 3's latency column
//! talks about. Results are recorded in EXPERIMENTS.md.
//!
//! Runs fully offline with no artifacts:
//!
//! ```bash
//! cargo run --release --example vgg16_e2e
//! # options: --requests 32 --batch 4 --variant vgg16-cifar --alpha 4 --skip-224
//! ```

use std::time::Instant;

use spectral_flow::coordinator::{
    BatcherConfig, EngineOptions, InferenceEngine, Server, ServerConfig, WeightMode,
};
use spectral_flow::runtime::BackendKind;
use spectral_flow::schedule::SchedulePolicy;
use spectral_flow::tensor::Tensor;
use spectral_flow::util::cli::Args;
use spectral_flow::util::error::Result;
use spectral_flow::util::rng::Pcg32;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let requests = args.opt_usize("requests", 24, "number of inference requests");
    let batch = args.opt_usize("batch", 4, "max batch size");
    let variant = args.opt("variant", "vgg16-cifar", "serving variant");
    let workers = args.opt_usize("workers", 1, "executor workers (one engine each)");
    let threads = args.opt_usize("backend-threads", 1, "interp per-tile threads per engine");
    let alpha = args.opt_usize("alpha", 4, "compression ratio α (≤1 = dense, >1 = sparse path)");
    let scheduler_name = args.opt(
        "scheduler",
        "exact-cover",
        "sparse access scheduler (exact-cover|lowest-index|off)",
    );
    let skip_224 = args.opt_bool("skip-224", "skip the single-image 224x224 run");
    args.maybe_help("vgg16_e2e: batched serving + single-image latency through the backend");
    let mode = WeightMode::from_alpha(alpha);
    let scheduler = SchedulePolicy::parse(&scheduler_name)?;

    println!("spectral-flow end-to-end driver");
    println!("===============================\n");

    // ---- Phase 1: batched serving on the CIFAR-scale VGG16 ---------------
    println!(
        "[1/2] serving {requests} requests ({variant}, α={} → {}, scheduler {}, \
         batch ≤ {batch}, {workers} worker(s) × {threads} backend thread(s))",
        mode.alpha(),
        if mode.alpha() > 1 { "sparse CSR MAC" } else { "dense MAC" },
        scheduler.label(),
    );
    let cfg = ServerConfig {
        artifacts_dir: "artifacts".into(),
        variant: variant.clone(),
        mode,
        seed: 7,
        batcher: BatcherConfig {
            max_batch: batch,
            max_wait: std::time::Duration::from_millis(10),
        },
        workers,
        engine: EngineOptions::builder()
            .backend(BackendKind::Interp { threads })
            .scheduler(scheduler)
            .build(),
    };
    let t0 = Instant::now();
    let server = Server::start(cfg)?;
    println!("  server up (weights + {variant} executables prepared) in {:?}", t0.elapsed());

    let client = server.client();
    let mut rng = Pcg32::new(99);
    let images: Vec<Tensor> = (0..requests)
        .map(|_| Tensor::randn(&[3, 32, 32], &mut rng, 1.0))
        .collect();

    let t1 = Instant::now();
    let mut pending = Vec::new();
    for img in images {
        pending.push(client.infer_async(img)?);
    }
    let mut ok = 0usize;
    let mut pe_util: Option<f64> = None;
    for rx in pending {
        let resp = rx.recv()??;
        assert_eq!(resp.logits.len(), 10);
        pe_util = pe_util.or(resp.pe_utilization);
        ok += 1;
    }
    let wall = t1.elapsed();
    let pm = server.pool_metrics()?;
    let m = &pm.merged;
    println!("  completed {ok}/{requests} requests in {wall:?}");
    for line in pm.report().lines() {
        println!("  {line}");
    }
    println!(
        "  throughput: {:.2} img/s (wall), per-request p50 {:?} / p95 {:?}",
        ok as f64 / wall.as_secs_f64(),
        m.p50().unwrap_or_default(),
        m.p95().unwrap_or_default()
    );
    if let Some(u) = pe_util {
        println!("  schedule PE utilization (responses): {:.1}%", 100.0 * u);
    }
    if let Some(s) = &m.schedule {
        for line in s.report_layers().lines() {
            println!("  sched {line}");
        }
    }
    server.shutdown()?;

    // ---- Phase 2: single-image 224×224 latency (Table 3's workload) ------
    if !skip_224 {
        println!("\n[2/2] single-image VGG16-224 forward (the paper's latency workload)");
        let t2 = Instant::now();
        let mut engine = InferenceEngine::new_with_opts(
            "artifacts",
            "vgg16-224",
            mode,
            7,
            BackendKind::Interp { threads },
            scheduler,
        )?;
        println!("  engine up in {:?} (13 conv layers)", t2.elapsed());
        let img = engine.synthetic_image(1);
        // warm once (first-touch allocations), then measure.
        let _ = engine.forward(&img)?;
        let t3 = Instant::now();
        let logits = engine.forward(&img)?;
        let dt = t3.elapsed();
        println!(
            "  forward(224x224) in {dt:?} → {} logits (argmax {})",
            logits.len(),
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        );
        println!(
            "  note: this is CPU wallclock of the software numerics path; the paper's\n\
             \x20 9 ms is the simulated U200 — see `accelerator_sim` for that row."
        );
    }
    println!("\nvgg16_e2e OK");
    Ok(())
}
